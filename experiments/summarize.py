"""Generate the EXPERIMENTS.md tables from experiments/dryrun/*.json and the
measured MoE benches from benchmarks/results/results.json (fig8/fig9).

  PYTHONPATH=src python experiments/summarize.py
"""
import glob
import json
import os

DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "dryrun")
RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "benchmarks", "results", "results.json")

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["granite-3-2b", "whisper-tiny", "arctic-480b", "qwen2-72b",
         "deepseek-v2-236b", "hymba-1.5b", "rwkv6-7b", "smollm-360m",
         "internvl2-76b", "starcoder2-15b"]


def load(arch, shape, mesh="16x16", tag=""):
    suffix = f"_{tag}" if tag else ""
    fn = os.path.join(DIR, f"{arch}_{shape}_{mesh}{suffix}.json")
    if not os.path.exists(fn):
        return None
    return json.load(open(fn))


def fmt_s(x):
    return f"{x:.3g}"


def roofline_table(tag=""):
    print(f"| arch | shape | compute_s | memory_s | collective_s | dominant | "
          f"useful | bound_s |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            r = load(arch, shape, tag=tag)
            if r is None or not r.get("ok"):
                print(f"| {arch} | {shape} | - | - | - | MISSING | - | - |")
                continue
            rl = r["roofline"]
            print(f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | "
                  f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                  f"{rl['dominant']} | {rl['useful_flops_ratio']:.3f} | "
                  f"{fmt_s(rl['step_s_bound'])} |")


def dryrun_table():
    print("| arch | shape | 16x16 | 2x16x16 | args GB/dev (16x16) | temp GB/dev |")
    print("|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            r1 = load(arch, shape, "16x16")
            r2 = load(arch, shape, "2x16x16")
            ok1 = "OK" if (r1 and r1.get("ok")) else "FAIL"
            ok2 = "OK" if (r2 and r2.get("ok")) else "FAIL"
            mem = r1["memory"] if r1 and r1.get("ok") else {}
            arg = mem.get("argument_bytes")
            tmp = mem.get("temp_bytes")
            print(f"| {arch} | {shape} | {ok1} | {ok2} | "
                  f"{arg / 1e9:.2f} | {tmp / 1e9:.2f} |" if arg is not None
                  else f"| {arch} | {shape} | {ok1} | {ok2} | - | - |")


def opt_delta_table():
    print("| arch | shape | bound base | bound opt | x | dominant base->opt |")
    print("|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            b = load(arch, shape)
            o = load(arch, shape, tag="opt")
            if not (b and b.get("ok") and o and o.get("ok")):
                continue
            rb, ro = b["roofline"], o["roofline"]
            x = rb["step_s_bound"] / max(ro["step_s_bound"], 1e-12)
            print(f"| {arch} | {shape} | {fmt_s(rb['step_s_bound'])} | "
                  f"{fmt_s(ro['step_s_bound'])} | {x:.2f}x | "
                  f"{rb['dominant']}->{ro['dominant']} |")


def moe_bench_table():
    """Measured MoE benches: fig8 (placement), fig9 (overlap), fig10
    (fwd+bwd train step, two-pass vs fused kernels)."""
    if not os.path.exists(RESULTS):
        print("(no benchmarks/results/results.json — run "
              "`PYTHONPATH=src python -m benchmarks.run --only fig8,fig9,fig10`)")
        return
    res = json.load(open(RESULTS))
    print("| bench | setting | us | detail |")
    print("|---|---|---|---|")
    for r in res.get("fig8", []):
        print(f"| fig8 | placement off | {r['us_off']:.0f} | "
              f"a2a_elems={r['a2a_elems_off']} drop={r['drop_off']:.3f} "
              f"imb={r['imbalance']:.2f} |")
        print(f"| fig8 | placement on | {r['us_on']:.0f} | "
              f"a2a_elems={r['a2a_elems_on']} shadow={r['num_shadow']} "
              f"cap_scale={r['capacity_scale']:.2f} drop={r['drop_on']:.3f} |")
    for r in res.get("fig9", []):
        wire0 = (f" wire_bytes={r['wire_bytes_serial']:.0f}"
                 if "wire_bytes_serial" in r else "")
        wire1 = (f" wire_bytes={r['wire_bytes_pipelined']:.0f}"
                 if "wire_bytes_pipelined" in r else "")
        print(f"| fig9 | serial | {r['us_serial']:.0f} | "
              f"all_to_all_ops={r['hlo_all_to_all_serial']}{wire0} |")
        print(f"| fig9 | pipelined x{r['n_chunks']} | {r['us_pipelined']:.0f} | "
              f"collective_permutes={r['hlo_collective_permute_pipelined']} "
              f"chunk_elems={r['chunk_elems']} "
              f"bit_exact={r['bit_exact']}{wire1} |")
        h = r.get("hier")
        if h:
            print(f"| fig9 | 2-level flat | {h['us_flat']:.0f} | "
                  f"inter_bytes={h['wire_bytes_flat_inter']:.0f} "
                  f"(flat: all bytes cross nodes) |")
            print(f"| fig9 | 2-level dropless | {h['us_hier']:.0f} | "
                  f"bit_exact={h['bit_exact']} "
                  f"intra={h['wire_bytes_hier_intra']:.0f} "
                  f"inter={h['wire_bytes_hier_inter']:.0f} |")
            print(f"| fig9 | 2-level auto bounds | {h['us_hier_auto']:.0f} | "
                  f"bound={h['ragged_bound_auto']}/{h['dropless_bound']} "
                  f"inter_bound={h['inter_bound_auto']}/"
                  f"{h['dropless_inter_bound']} "
                  f"inter={h['wire_bytes_auto_inter']:.0f} "
                  f"drop={h['drop_frac_auto']:.3f} |")
    for r in res.get("fig11", []):
        print(f"| fig11 | serve {r['mode']} ({r['slots']} slots) | "
              f"{1e6 / max(r['tok_s'], 1e-9):.0f} | "
              f"tok_s={r['tok_s']:.1f} p50={r['p50_ms']:.1f}ms "
              f"p99={r['p99_ms']:.1f}ms ticks={r['ticks']} "
              f"replans={r['replans']} |")
    for r in res.get("fig10", []):
        if r.get("distributed"):
            split = ("" if "wire_bytes_inter" not in r else
                     f" intra={r['wire_bytes_intra']:.0f}"
                     f" inter={r['wire_bytes_inter']:.0f}")
            print(f"| fig10 | dist {r['dispatch']}/{r['wire_dtype']} "
                  f"x{r['ranks']} | {r['us']:.0f} | "
                  f"wire_bytes={r['wire_bytes']:.0f} "
                  f"hlo_fwd_bytes={r['hlo_fwd_bytes']:.0f} "
                  f"imbalance={r['imbalance']:.2f}{split} |")
        else:
            print(f"| fig10 | {r['dispatch']}/{r['impl']} | {r['us']:.0f} | "
                  f"fwd+bwd tokens={r['tokens']} "
                  f"materializes_MH={r['materializes_mh']} |")
    _wire_evidence(res)


def _wire_evidence(res):
    """Measured (device counter) vs modeled (optimized HLO) wire bytes —
    the fig9/fig10 evidence block collected by benchmarks/run.py."""
    ws = res.get("wire_summary") or {}
    if not ws:
        return
    print("\n### Wire-byte evidence (device counters vs optimized HLO)\n")
    print("| source | setting | measured bytes | HLO bytes |")
    print("|---|---|---|---|")
    f9 = ws.get("fig9", {})
    for key in ("serial", "pipelined", "bf16"):
        m, h = f9.get(f"wire_bytes_{key}"), f9.get(f"hlo_bytes_{key}")
        if m is not None and h is not None:
            print(f"| fig9 | {key} | {m:.0f} | {h:.0f} |")
    f9h = ws.get("fig9_hier", {})
    for key in ("flat", "hier", "auto"):
        m, h = f9h.get(f"wire_bytes_{key}"), f9h.get(f"hlo_bytes_{key}")
        if m is not None and h is not None:
            inter = f9h.get(f"wire_bytes_{key}_inter",
                            f9h.get("wire_bytes_flat_inter")
                            if key == "flat" else None)
            tail = f" (inter={inter:.0f})" if inter is not None else ""
            print(f"| fig9 | 2-level {key} | {m:.0f} | {h:.0f}{tail} |")
    for key, v in sorted(ws.get("fig10", {}).items()):
        split = ("" if "wire_bytes_inter" not in v else
                 f" (inter={v['wire_bytes_inter']:.0f})")
        print(f"| fig10 | {key} | {v['wire_bytes']:.0f} | "
              f"{v['hlo_fwd_bytes']:.0f}{split} |")


if __name__ == "__main__":
    print("## Baseline roofline (single-pod 16x16)\n")
    roofline_table()
    print("\n## Dry-run status + memory\n")
    dryrun_table()
    print("\n## Optimized (head_aware+constrain_tokens+serve_tp+cache_seq)\n")
    opt_delta_table()
    print("\n## Measured MoE benches (fig8 placement, fig9 overlap)\n")
    moe_bench_table()
