"""End-to-end driver: train a ~100M-param MoE GPT (the paper's §5.4 setup,
fmoefy'd GPT with experts) for a few hundred steps on the synthetic stream.

  PYTHONPATH=src python examples/train_moe_lm.py --steps 300
  PYTHONPATH=src python examples/train_moe_lm.py --steps 300 --dense  # baseline

The default config is ~100M params (12 layers, d=512, 16 experts top-2) —
sized so a few hundred CPU steps finish in minutes while exercising the full
stack: gate -> dispatch -> expert GeMM -> combine -> balance losses -> AdamW
-> checkpoint.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig
from repro.core.balance import MoEMetrics
from repro.core.monitor import LoadMonitor
from repro.data import SyntheticLM
from repro.launch.train import make_train_step
from repro.models import lm
from repro.optim import AdamW


def build_config(dense: bool, layers: int, d_model: int) -> ModelConfig:
    return ModelConfig(
        name="gpt-moe-100m" if not dense else "gpt-dense-100m",
        family="dense" if dense else "moe",
        num_layers=layers, d_model=d_model, d_ff=4 * d_model,
        vocab_size=8192,
        attention=AttentionConfig(num_heads=8, num_kv_heads=8,
                                  head_dim=d_model // 8),
        # d_h halved so active FLOPs match the dense baseline (paper §5.4)
        moe=None if dense else MoEConfig(num_experts=16, top_k=2,
                                         d_expert_hidden=2 * d_model),
        norm="layernorm", act="gelu",
        dtype="float32", param_dtype="float32", remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d_model", type=int, default=512)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = build_config(args.dense, args.layers, args.d_model)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.active_param_count() / 1e6:.1f}M active)")

    opt = AdamW(lr=1e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, warmup=20,
                                      total_steps=args.steps))
    data = SyntheticLM(cfg.vocab_size, args.seq, seed=0)
    monitor = None if args.dense else LoadMonitor(cfg.moe.num_experts)

    t0 = time.time()
    for i, batch in enumerate(data.batches(args.batch)):
        if i >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch, jnp.int32(i))
        if monitor is not None:
            # the paper's §6 load-balance monitor, fed every step
            monitor.update(MoEMetrics(m["aux_loss"], m["z_loss"],
                                      m["load"], m["drop_frac"]))
        if i % 20 == 0 or i == args.steps - 1:
            extra = (f" drop={float(m['drop_frac']):.1%}"
                     if not args.dense else "")
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}{extra}  "
                  f"[{time.time() - t0:.0f}s]", flush=True)
    if args.ckpt:
        save(args.ckpt, {"params": params}, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
