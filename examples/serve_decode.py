"""Serve a small model with batched requests: prefill the prompts, then
decode with the ring-buffer KV cache (the decode_32k path at CPU scale).

  PYTHONPATH=src python examples/serve_decode.py --arch smollm-360m --reduced
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch.serve import generate
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt_len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, num_layers=4, d_model=256)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    # batched "requests": random prompts of equal length
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    seqs = generate(params, cfg, prompts, steps=args.gen, cache_len=128,
                    temperature=0.8, rng=jax.random.PRNGKey(2))
    dt = time.time() - t0
    print(f"{args.batch} requests x {args.gen} new tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s on CPU)")
    print("first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
