"""Quickstart: build an MoE layer, route tokens, inspect the load monitor.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.naive import moe_loop_masked


def main() -> None:
    # 1. An MoE FFN: 8 experts, top-2 gating (paper Algorithm 1)
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=256,
                    capacity_factor=1.5)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 128, cfg)

    # 2. Route a batch of tokens through the reordered computation (Fig 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 128))
    y, metrics = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg))(params, x)
    print(f"output: {y.shape}, aux_loss={float(metrics.aux_loss):.3f}, "
          f"dropped={float(metrics.drop_frac):.1%}")
    print("per-expert load:", [f"{v:.2f}" for v in metrics.load.tolist()])

    # 3. It is numerically identical to the naive per-expert loop
    y_naive = moe_loop_masked(params, x, cfg)
    print("max |fast - naive| =", float(jnp.abs(y - y_naive).max()))

    # 4. The same layer runs distributed: see examples/expert_parallel.py
    print("ok")


if __name__ == "__main__":
    main()
