"""Distributed expert parallelism demo (paper §3.2): the same FMoE layer on
an 8-worker mesh, with the all-to-all global data exchange visible in HLO.

  PYTHONPATH=src python examples/expert_parallel.py
(spawns its own 8 fake devices — run as a standalone script, not inside a
process that already initialized jax)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.naive import moe_loop_masked


def main() -> None:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=256,
                    capacity_factor=2.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 128, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, 128))

    dist = fmoe.DistConfig(mesh, ("data", "model"))  # tokens over all 8 workers
    print(f"mode={dist.mode}: 8 experts sharded over {dist.expert_parallelism} "
          f"model-parallel workers, 2-way data parallel")

    fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg, dist=dist))
    with mesh:
        y, metrics = fn(params, x)
        hlo = fn.lower(params, x).compile().as_text()
    n_a2a = hlo.count(" all-to-all(") + hlo.count(" all-to-all-start(")
    print(f"all-to-all ops in compiled HLO: {n_a2a} (dispatch + counts + return)")

    y_ref = moe_loop_masked(params, x, cfg)
    print("max |distributed - local reference| =",
          float(jnp.abs(y - y_ref).max()))
    print("per-expert load:", [f"{v:.2f}" for v in metrics.load.tolist()])


if __name__ == "__main__":
    main()
