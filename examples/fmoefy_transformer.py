"""The paper's Listing 1: turn an existing dense transformer into an MoE
model with one call — here on the assigned granite-3-2b config (reduced).

  PYTHONPATH=src python examples/fmoefy_transformer.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.fmoefy import fmoefy
from repro.models import lm


def main() -> None:
    dense_cfg = get_config("granite-3-2b")
    # --- the 2-line transformation (paper Listing 1) ---
    moe_cfg = fmoefy(dense_cfg, num_experts=96, top_k=2)
    # ---------------------------------------------------
    print(f"{dense_cfg.name}:  {dense_cfg.param_count() / 1e9:.2f}B params")
    print(f"{moe_cfg.name}: {moe_cfg.param_count() / 1e9:.2f}B params "
          f"({moe_cfg.active_param_count() / 1e9:.2f}B active — same FLOPs)")

    # run the MoE-ified model (reduced to CPU scale)
    cfg = reduced(moe_cfg)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    logits, metrics = jax.jit(
        lambda p, t: lm.forward(p, cfg, t))(params, tokens)
    print(f"reduced forward: {logits.shape}, "
          f"aux={float(metrics.aux_loss):.3f}, "
          f"load across {cfg.moe.num_experts} experts: "
          f"{[f'{v:.2f}' for v in metrics.load.tolist()]}")


if __name__ == "__main__":
    main()
