"""Smoke tests for the non-assigned pool configs: the paper's own fastmoe-gpt
(96 experts), its dense baseline, and switch-base-128 (top-1 routing)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import fmoe, naive
from repro.launch.train import make_train_step
from repro.models import lm
from repro.optim import AdamW


@pytest.mark.parametrize("name", ["fastmoe-gpt", "fastmoe-gpt-dense",
                                  "switch-base-128"])
def test_extra_arch_smoke(name):
    cfg = reduced(get_config(name))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    p2, _, m = step(params, opt.init(params), batch, jnp.int32(0))
    assert np.isfinite(float(m["loss"]))


def test_top1_switch_gate_matches_naive():
    """k=1 (Switch) path through dispatch/combine == naive loop."""
    cfg = get_config("switch-base-128")
    moe = dataclasses.replace(cfg.moe, num_experts=4, d_expert_hidden=32,
                              capacity_factor=8.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, moe, act="gelu")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    y, m = fmoe.fmoe_apply(params, x, moe, act="gelu")
    y_ref = naive.moe_loop_masked(params, x, moe, act="gelu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    # top-1: every token contributes exactly one expert -> weights == 1
    from repro.core.gate import gate_forward
    g = gate_forward(params["router"], x.reshape(-1, 16), moe)
    np.testing.assert_allclose(np.asarray(g.combine_weights), 1.0, rtol=1e-5)


def test_paper_gpt_96_experts_config():
    cfg = get_config("fastmoe-gpt")
    assert cfg.moe.num_experts == 96 and cfg.moe.top_k == 2
    # §5.4: d_h halved so active FLOPs ~= dense baseline
    dense = get_config("fastmoe-gpt-dense")
    ratio = cfg.active_param_count() / dense.param_count()
    assert abs(ratio - 1.0) < 0.05, ratio
