"""FMoE layer equivalence + gradient tests.

The key correctness claim of the reordered computation (paper §4): the
scatter->batched-GeMM->gather path is numerically identical to the naive
per-expert formulation (paper Algorithm 1 / the Rau-2019 baseline)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import fmoe, naive

CFG = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32, capacity_factor=8.0)


@pytest.fixture(scope="module")
def setup():
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    return params, x


def test_capacity_matches_naive_loop(setup):
    params, x = setup
    y, _ = fmoe.fmoe_apply(params, x, CFG)
    y_ref = naive.moe_loop_masked(params, x, CFG)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_capacity_matches_per_sample(setup):
    params, x = setup
    y, _ = fmoe.fmoe_apply(params, x, CFG)
    y_ref = naive.moe_per_sample(params, x, CFG)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_ragged_matches_naive(setup):
    params, x = setup
    cfg = dataclasses.replace(CFG, dispatch="ragged")
    y, _ = fmoe.fmoe_apply(params, x, cfg)
    y_ref = naive.moe_loop_masked(params, x, CFG)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_pallas_expert_fn_matches(setup):
    params, x = setup
    y_e, _ = fmoe.fmoe_apply(params, x, CFG, impl="einsum")
    y_p, _ = fmoe.fmoe_apply(params, x, CFG, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_p), atol=1e-4)


@pytest.mark.parametrize("act", ["swiglu", "gelu"])
def test_acts(act):
    params = fmoe.fmoe_init(jax.random.PRNGKey(2), 16, CFG, act=act)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 16))
    y, _ = fmoe.fmoe_apply(params, x, CFG, act=act)
    y_ref = naive.moe_loop_masked(params, x, CFG, act=act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_gradients_match_naive(setup):
    params, x = setup

    def loss_fast(p):
        y, _ = fmoe.fmoe_apply(p, x, CFG)
        return (y ** 2).mean()

    def loss_naive(p):
        return (naive.moe_loop_masked(p, x, CFG) ** 2).mean()

    g1 = jax.grad(loss_fast)(params)
    g2 = jax.grad(loss_naive)(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-5), g1, g2)


def test_shared_experts_and_dense_residual():
    cfg = dataclasses.replace(CFG, num_shared_experts=2, dense_residual=True)
    params = fmoe.fmoe_init(jax.random.PRNGKey(4), 16, cfg, d_ff_dense=64)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 16))
    y, _ = fmoe.fmoe_apply(params, x, cfg)
    # removing shared/dense parts changes the output (they're live)
    y_routed, _ = fmoe.fmoe_apply(
        {k: v for k, v in params.items() if k in ("router", "experts")}, x, cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y_routed))


def test_drop_metric_nonzero_at_tight_capacity():
    cfg = dataclasses.replace(CFG, capacity_factor=0.25)
    params = fmoe.fmoe_init(jax.random.PRNGKey(6), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 16, 16))
    _, m = fmoe.fmoe_apply(params, x, cfg)
    assert float(m.drop_frac) > 0.0


def test_metrics_load_sums_to_one(setup):
    params, x = setup
    _, m = fmoe.fmoe_apply(params, x, CFG)
    np.testing.assert_allclose(float(m.load.sum()), 1.0, rtol=1e-5)
