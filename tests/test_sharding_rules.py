"""Sharding-rule + sync-tag tests (paper §3.2 heterogeneity-aware sync).

These run on a single device using abstract meshes — they verify the *rules*,
not execution (tests/test_distributed.py covers execution)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_abstract_mesh
from repro.configs import get_config, reduced
from repro.core.sync import fastmoe_tag, grad_sync_axes, spec_axes
from repro.launch.sharding import _flat_paths, spec_for, tree_specs
from repro.models import lm


def _mesh(shape=(16, 16), axes=("data", "model")):
    return make_abstract_mesh(shape, axes)


@pytest.fixture(scope="module")
def arctic_specs():
    cfg = get_config("arctic-480b")
    mesh = _mesh()
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = tree_specs(shapes, mesh)
    return dict(_flat_paths(shapes)), dict(_flat_paths(specs))


def test_expert_params_shard_over_model(arctic_specs):
    shapes, specs = arctic_specs
    # (L, E, d, h): experts over model, hidden dim FSDP over data (the layout
    # that coincides with expert-internal TP — see sharding.py RULES comment)
    s = specs["layers/ffn/experts/wi_gate"]
    assert s == P(None, "model", None, "data")
    assert specs["layers/ffn/experts/wo"] == P(None, "model", "data", None)


def test_router_replicated_world_tag(arctic_specs):
    shapes, specs = arctic_specs
    s = specs["layers/ffn/router/w"]
    assert spec_axes(s) == set()
    assert fastmoe_tag("layers/ffn/router/w", s, ("data", "model")) == "world"


def test_attention_tp_dp_tag(arctic_specs):
    shapes, specs = arctic_specs
    s = specs["layers/attn/wq/w"]
    assert "model" in spec_axes(s)
    assert fastmoe_tag("layers/attn/wq/w", s, ("data", "model")) == "dp"


def test_expert_none_tag():
    s = P(None, "model", "data", None)
    tag = fastmoe_tag("layers/ffn/experts/wi_gate", s, ("data", "model"))
    assert tag == "none"


def test_grad_sync_axes_complement():
    assert grad_sync_axes(P("model", None), ("pod", "data", "model")) == ("pod", "data")
    assert grad_sync_axes(P(None), ("data", "model")) == ("data", "model")


def test_divisibility_guard_replicates():
    # vocab 49155 is not divisible by model=16 -> replicated on that dim
    spec = spec_for("embed/table", (49155, 2048), _mesh(), stacked=False)
    assert spec[0] is None
    assert spec[1] == ("data",) or spec[1] == "data"


def test_stacked_layer_dim_never_sharded():
    spec = spec_for("layers/attn/wq/w", (40, 2048, 2048), _mesh(), stacked=True)
    assert spec[0] is None


@pytest.mark.parametrize("arch", ["qwen2-72b", "deepseek-v2-236b", "rwkv6-7b",
                                  "hymba-1.5b", "whisper-tiny"])
def test_all_params_get_valid_specs(arch):
    cfg = get_config(arch)
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    flat_shapes = dict(_flat_paths(shapes))
    flat_specs = dict(_flat_paths(tree_specs(shapes, mesh)))
    assert set(flat_shapes) == set(flat_specs)
    for path, spec in flat_specs.items():
        shape = flat_shapes[path].shape
        assert len(spec) <= len(shape), (path, spec, shape)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert shape[i] % size == 0, (path, spec, shape)


def test_head_aware_rules():
    """Arch-aware overrides: heads not divisible by the model axis =>
    replicate the offending projections (§Perf, avoids SPMD replication)."""
    from repro.launch.sharding import rules_for, tree_specs
    mesh = _mesh()
    # arctic: H=56, KV=8 — both indivisible by 16 -> q/k/v/wo replicated
    cfg = get_config("arctic-480b")
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = dict(_flat_paths(tree_specs(shapes, mesh, cfg=cfg)))
    assert "model" not in spec_axes(specs["layers/attn/wq/w"])
    assert "model" not in spec_axes(specs["layers/attn/wk/w"])
    # qwen2: H=64 divisible, KV=8 not -> q sharded, k/v replicated
    cfg = get_config("qwen2-72b")
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = dict(_flat_paths(tree_specs(shapes, mesh, cfg=cfg)))
    assert "model" in spec_axes(specs["layers/attn/wq/w"])
    assert "model" not in spec_axes(specs["layers/attn/wk/w"])


def test_serve_mode_drops_fsdp():
    from repro.launch.sharding import tree_specs
    mesh = _mesh()
    cfg = get_config("qwen2-72b")
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    train = dict(_flat_paths(tree_specs(shapes, mesh, "train")))
    serve = dict(_flat_paths(tree_specs(shapes, mesh, "serve")))
    # FSDP (data) sharding present in train, absent in serve
    assert "data" in spec_axes(train["layers/ffn/wi_gate"])
    assert "data" not in spec_axes(serve["layers/ffn/wi_gate"])
    # TP (model) retained in both
    assert "model" in spec_axes(serve["layers/ffn/wi_gate"])


def test_cache_seq_sharding():
    from repro.launch.sharding import cache_specs
    from repro.models import lm as _lm
    cfg = get_config("qwen2-72b")
    mesh = _mesh()
    cache = jax.eval_shape(lambda: _lm.init_cache(cfg, 128, 32768))
    specs = dict(_flat_paths(cache_specs(cache, mesh, 128, seq_shard=True)))
    assert specs["k"][2] == "model"  # (L, B, W, KV, hd): window over model
    assert specs["positions"][2] == "model"
    default = dict(_flat_paths(cache_specs(cache, mesh, 128)))
    assert default["k"][-1] == "model"  # head_dim sharded by default


def test_sync_report_covers_three_tags():
    cfg = get_config("deepseek-v2-236b")
    mesh = _mesh()
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    flat_specs = dict(_flat_paths(tree_specs(shapes, mesh)))
    tags = {fastmoe_tag(p, s, ("data", "model")) for p, s in flat_specs.items()}
    assert tags == {"world", "dp", "none"}
