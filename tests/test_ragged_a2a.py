"""Ragged (dropless) expert-parallel exchange — ISSUE 4 tentpole tests.

The distributed ragged path must be *the same function* as the single-rank
ragged path: per-row outputs bit-identical (the exchange only moves rows),
expert grads bit-identical on the acceptance mesh (1x4, fused impl — same
rows, same tile partitioning, same f32 accumulation order), and composed
correctly with the capacity a2a, Zipf skew (ranks receiving zero tokens),
the bf16 wire, overlap chunking, and bounded (dropping) shards.

Host tests exercise the pure index math of core/dispatch through the
multi-rank emulation oracle in tests/dist_utils.py; multi-device cases run
in subprocesses via the same harness (the main process keeps its single CPU
device).
"""
import jax.numpy as jnp
import numpy as np

import dist_utils as du
from repro.core import dispatch as D


# ---------------------------------------------------------------------------
# Host-level: the send/recv plan index math, emulated end to end in numpy
# ---------------------------------------------------------------------------


def test_xplan_recv_roundtrip():
    rng = np.random.default_rng(0)
    mp, e_local, t, k = 4, 2, 8, 2
    bound = t * k  # dropless
    rows, outs = du.emulate_ragged_exchange(rng, mp, e_local, t, k, bound)
    total_seen = 0
    for r, (compact, gs_local, incoming) in enumerate(outs):
        # group sizes = what every source assigned to this rank's experts
        want = np.zeros(e_local, np.int64)
        for s in range(mp):
            ids = rows[s][0]
            for e in range(e_local):
                want[e] += (ids == r * e_local + e).sum()
        np.testing.assert_array_equal(gs_local, want)
        # compact rows: expert segments contiguous, src-major inside, and
        # every row tagged with the expert that owns its segment
        off = 0
        for e in range(e_local):
            seg = compact[off:off + gs_local[e]]
            assert (seg[:, 0] >= 0).all(), "hole inside a valid segment"
            # src-major: source ranks non-decreasing within the segment
            assert (np.diff(seg[:, 0]) >= 0).all()
            for src, orig in seg:
                assert rows[src][0][orig] == r * e_local + e
            off += gs_local[e]
        assert (compact[off:, 0] == -1).all(), "rows past the valid prefix"
        total_seen += int(gs_local.sum())
    assert total_seen == mp * t * k  # dropless: every (token, slot) row lands


def test_xplan_bounded_drops_trailing_experts():
    # one peer overloaded: the bound truncates its trailing experts first
    gs = jnp.asarray([5, 4, 0, 1], jnp.int32)  # 2 peers x 2 experts, 10 rows
    xp = D.make_ragged_xplan(gs, 10, 4, 2, bound=6)
    np.testing.assert_array_equal(np.asarray(xp.peer_counts), [[5, 1], [0, 1]])
    assert int(xp.keep.sum()) == 7  # 3 of peer-0's expert-1 rows dropped
    assert int(xp.num_owned_rows) == 10
    dest = np.asarray(xp.send_dest)
    assert (np.sort(dest[dest < 12]) == np.r_[0:6, 6:7]).all()


def test_xplan_shadow_tail_stays_local():
    # experts [2, 4) shadowed: their rows must not enter the send buffer
    gs = jnp.asarray([2, 3, 4, 1], jnp.int32)
    xp = D.make_ragged_xplan(gs, 10, 2, 2, bound=10)
    assert int(xp.num_owned_rows) == 5
    dest = np.asarray(xp.send_dest)
    assert (dest[5:] == 20).all()  # shadow tail dropped from the exchange
    assert (dest[:5] < 20).all()


def test_recv_compact_zero_source():
    # a source rank that sends nothing: its whole shard is padding
    incoming = jnp.asarray([[0, 0], [3, 2]], jnp.int32)
    cplan, gs = D.ragged_recv_compact(incoming, bound=8)
    np.testing.assert_array_equal(np.asarray(gs), [3, 2])
    cp = np.asarray(cplan)
    assert (cp[:8] == 16).all()  # rank 0's shard entirely invalid
    np.testing.assert_array_equal(cp[8:13], [0, 1, 2, 3, 4])


# ---------------------------------------------------------------------------
# Multi-device: equality with the single-rank ragged path + composition
# ---------------------------------------------------------------------------

_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    env = du.moe_env(dispatch="ragged", capacity_factor=1.25)
    mesh = du.make_mesh()
"""


def test_ragged_a2a_matches_single_rank_and_capacity():
    out = du.run(_SETUP + """
    import dataclasses
    for impl in ("einsum", "pallas", "fused"):
        y_ref, m_ref = du.oracle(env, impl=impl)
        y, m = du.dist_apply(env, mesh,
                             fmoe.DistConfig(mesh, ("data", "model")),
                             impl=impl)
        du.assert_close(y, y_ref, 1e-5, msg=impl)
        np.testing.assert_allclose(np.asarray(m.load), np.asarray(m_ref.load),
                                   atol=1e-6)
        assert float(m.drop_frac) == 0.0  # dropless by construction
        # psum mode (tokens not sharded over the expert axis)
        yp, mp_ = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, ("data",)),
                                impl=impl)
        du.assert_close(yp, y_ref, 1e-5, msg=impl)
        assert float(mp_.drop_frac) == 0.0
    # vs the capacity a2a under uniform-ish load (cf large enough: no drops)
    envc = du.moe_env(dispatch="capacity", capacity_factor=8.0)
    ycap, mcap = du.dist_apply(envc, mesh,
                               fmoe.DistConfig(mesh, ("data", "model")))
    yrag, _ = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, ("data", "model")))
    assert float(mcap.drop_frac) == 0.0
    du.assert_close(ycap, yrag, 1e-5)
    print("ragged matches ok")
    """)
    assert "ragged matches ok" in out


def test_ragged_bit_exact_on_1x4_fused():
    """Acceptance: --dispatch ragged --impl fused --mesh 1x4 — forward
    outputs AND expert grads bit-identical to the single-rank ragged path
    (same rows, same tile layout, same f32 accumulation order).  The router
    grad is x^T @ dlogits at a different GEMM shape (t vs T rows), so it
    matches to f32 reassociation tolerance, not bitwise — that GEMM is
    outside the exchange."""
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    env = du.moe_env(dispatch="ragged", capacity_factor=1.25)
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    dist = fmoe.DistConfig(mesh, ("data", "model"))
    cfg = env.cfg

    def loss(p, x, dist):
        y, _ = fmoe.fmoe_apply(p, x, cfg, dist=dist, impl="fused")
        return (y ** 2).mean()

    def train(dist, steps=3, lr=0.1):
        # SGD on the expert weights (everything that crosses the exchange).
        # The router weight stays frozen: its grad is x^T @ dlogits at a
        # different GEMM shape per sharding, bitwise-equal only up to f32
        # reassociation, and feeding that ulp back would cascade.
        p = env.params
        step = jax.jit(lambda p, x: (
            fmoe.fmoe_apply(p, x, cfg, dist=dist, impl="fused")[0],
            jax.grad(loss)(p, x, dist)))
        ys, gr = [], None
        for _ in range(steps):
            with mesh:
                y, g = step(p, env.x)
            p = {**p, "experts": jax.tree.map(lambda a, b: a - lr * b,
                                              p["experts"], g["experts"])}
            ys.append(np.asarray(y))
            gr = g
        return ys, p, gr

    ys0, p0, g0 = train(None)
    ys1, p1, g1 = train(dist)
    for a, b in zip(ys0, ys1):
        du.assert_bit_exact(a, b)  # bitwise, every step
    for k in ("wi_gate", "wi_up", "wo"):
        du.assert_bit_exact(p0["experts"][k], p1["experts"][k])
    du.assert_grads_match(g0, g1)
    print("1x4 fused bit-exact ok")
    """, devices=4)
    assert "1x4 fused bit-exact ok" in out


def test_ragged_chunked_wire_and_skew():
    """overlap_chunks (ppermute micro-shards) and the bf16 wire compose with
    the ragged exchange; Zipf-style skew routing everything to two experts
    leaves half the ranks receiving zero tokens and still matches the
    single-rank path with zero drops."""
    out = du.run(_SETUP + """
    y0, m0 = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, ("data", "model")))
    for nc in (2, 4, 3):
        y1, m1 = du.dist_apply(env, mesh, fmoe.DistConfig(
            mesh, ("data", "model"), overlap_chunks=nc))
        du.assert_bit_exact(y1, y0, msg=nc)
        np.testing.assert_array_equal(np.asarray(m0.load), np.asarray(m1.load))
    yb, _ = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, ("data", "model"),
                                                     wire_dtype="bf16"))
    yb4, _ = du.dist_apply(env, mesh, fmoe.DistConfig(
        mesh, ("data", "model"), wire_dtype="bf16", overlap_chunks=4))
    err = float(jnp.abs(yb - y0).max())
    assert 0 < err < 0.05, err  # bf16 quantization, and the cast happened
    du.assert_bit_exact(yb4, yb)
    # skew: all tokens to experts {0, 1} -> ranks owning experts 4..7 get 0
    skew = du.skew_router(env)
    y_ref, m_ref = du.oracle(skew, impl="fused")
    y2, m2 = du.dist_apply(skew, mesh, fmoe.DistConfig(mesh, ("data", "model")),
                           impl="fused")
    du.assert_close(y2, y_ref, 1e-5)
    assert float(m2.drop_frac) == 0.0
    load = np.asarray(m2.load)
    np.testing.assert_allclose(load[:2], [0.5, 0.5], atol=1e-6)
    assert (load[2:] == 0).all()
    print("chunked+wire+skew ok")
    """)
    assert "chunked+wire+skew ok" in out


def test_ragged_composes_with_shadow_placement():
    """Shadowed hot experts are served locally outside the exchange: outputs
    identical, monitor load still in logical order, and the shadow filler
    composes with chunking."""
    out = du.run(_SETUP + """
    from repro.placement import from_logical
    y0, m0 = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, ("data", "model")))
    load = np.asarray(m0.load)
    plan = du.hot_shadow_plan(load, 4, 4)
    pp = from_logical(env.params, plan)
    for nc in (0, 4):
        y1, m1 = du.dist_apply(env, mesh, fmoe.DistConfig(
            mesh, ("data", "model"), placement=plan, overlap_chunks=nc),
            params=pp)
        du.assert_close(y1, y0, 1e-5, msg=nc)
        np.testing.assert_allclose(np.asarray(m1.load), load, atol=1e-6)
    print("shadow compose ok")
    """)
    assert "shadow compose ok" in out


def test_ragged_bound_trades_drops():
    """A sub-dropless ragged_bound drops the over-bound rows (tracked in
    drop_frac) and still produces finite outputs; the default bound drops
    nothing on the same input."""
    out = du.run(_SETUP + """
    skew = du.skew_router(env)  # all rows to experts 0/1 = rank 0's shard
    _, m_full = du.dist_apply(skew, mesh,
                              fmoe.DistConfig(mesh, ("data", "model")))
    assert float(m_full.drop_frac) == 0.0
    yb, mb = du.dist_apply(skew, mesh, fmoe.DistConfig(
        mesh, ("data", "model"), ragged_bound=8))
    # per rank: 32 rows all to peer 0, bound 8 -> 24/32 dropped
    np.testing.assert_allclose(float(mb.drop_frac), 0.75, atol=1e-6)
    assert np.isfinite(np.asarray(yb)).all()
    print("bound drops ok")
    """)
    assert "bound drops ok" in out


def test_train_cli_runs_ragged_mesh():
    """launch/train.py accepts --dispatch ragged with --mesh (the ISSUE-4
    unlock) and takes optimizer steps."""
    out = du.run_cli(
        ["repro.launch.train", "--arch", "fastmoe-gpt", "--reduced",
         "--steps", "2", "--batch", "4", "--seq", "32", "--mesh", "1x4",
         "--dispatch", "ragged", "--impl", "fused", "--overlap_chunks", "2",
         "--log_every", "1"], devices=4)
    assert "done: 2 steps" in out, out
