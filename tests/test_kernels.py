"""Pallas kernel sweeps vs pure-jnp oracles (shapes x dtypes + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dispatch import make_ragged_plan
from repro.kernels import ops, ref
from repro.kernels.grouped_gemm import grouped_gemm_tiled


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,K,N", [(2, 16, 16), (4, 64, 96), (8, 128, 64)])
def test_grouped_matmul_sweep(E, K, N, dtype):
    rng = np.random.default_rng(E * 100 + N)
    sizes = rng.multinomial(200, np.ones(E) / E)
    gs = jnp.asarray(sizes, jnp.int32)
    M = int(gs.sum())
    x = _rand((M, K), dtype, seed=1)
    w = _rand((E, K, N), dtype, seed=2)
    y = ops.grouped_matmul(x, w, gs, "pallas", 16)
    y_ref = ref.grouped_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32), gs)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               **TOLS[dtype])


def test_grouped_matmul_empty_groups():
    gs = jnp.array([0, 10, 0, 6], jnp.int32)
    x = _rand((16, 32), jnp.float32)
    w = _rand((4, 32, 24), jnp.float32)
    y = ops.grouped_matmul(x, w, gs, "pallas", 8)
    y_ref = ref.grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5)


def test_grouped_matmul_xla_path():
    gs = jnp.array([3, 5], jnp.int32)
    x = _rand((8, 16), jnp.float32)
    w = _rand((2, 16, 8), jnp.float32)
    y = ops.grouped_matmul(x, w, gs, "xla")
    y_ref = ref.grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=1e-5)


def test_grouped_matmul_grad_matches_ref():
    gs = jnp.array([12, 4, 20], jnp.int32)
    x = _rand((36, 24), jnp.float32, 3)
    w = _rand((3, 24, 16), jnp.float32, 4)

    gk = jax.grad(lambda x, w: (ops.grouped_matmul(x, w, gs, "pallas", 8) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: (ref.grouped_matmul_ref(x, w, gs) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(E=st.sampled_from([2, 4]), bm=st.sampled_from([8, 16]),
       K=st.sampled_from([8, 32]), N=st.sampled_from([8, 24]),
       seed=st.integers(0, 100))
def test_grouped_matmul_property(E, bm, K, N, seed):
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.integers(0, 30, E), jnp.int32)
    M = max(int(gs.sum()), 1)
    gs = gs.at[0].add(M - int(gs.sum()))
    x = _rand((M, K), jnp.float32, seed)
    w = _rand((E, K, N), jnp.float32, seed + 1)
    y = ops.grouped_matmul(x, w, gs, "pallas", bm)
    y_ref = ref.grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4,
                               atol=1e-4)


def test_tiled_kernel_direct_equal_groups():
    """Equal tile-aligned groups exercise the kernel without padding."""
    E, per, K, N, bm = 4, 32, 64, 48, 16
    x = _rand((E * per, K), jnp.float32, 7)
    w = _rand((E, K, N), jnp.float32, 8)
    tile_group = jnp.repeat(jnp.arange(E, dtype=jnp.int32), per // bm)
    y = grouped_gemm_tiled(x, w, tile_group, bm=bm, interpret=True)
    y_ref = ref.grouped_matmul_ref(x, w, jnp.full((E,), per, jnp.int32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_tokens(dtype):
    x = _rand((64, 128), dtype)
    idx = jnp.asarray(np.random.default_rng(0).integers(0, 64, 50), jnp.int32)
    y = ops.gather_tokens(x, idx)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref.gather_rows_ref(x, idx)))


@pytest.mark.parametrize("k", [1, 2, 4])
def test_combine_tokens(k):
    rng = np.random.default_rng(k)
    src = _rand((32, 128), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, (20, k)), jnp.int32)
    w = jnp.asarray(rng.random((20, k)), jnp.float32)
    y = ops.combine_tokens(src, idx, w)
    y_ref = ref.combine_topk_ref(src, idx, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5,
                               atol=1e-6)


def test_kernel_shuffle_roundtrip_with_ragged_plan():
    """gather_tokens + combine via kernels reproduces identity for identity
    experts — the full Fig-4 pipeline through Pallas."""
    T, E, k, d = 24, 4, 2, 128
    x = _rand((T, d), jnp.float32, 9)
    ids = jnp.asarray(np.random.default_rng(1).integers(0, E, (T, k)), jnp.int32)
    plan = make_ragged_plan(ids, E)
    xs = ops.gather_tokens(x, plan.token_rows)
    # identity expert: outputs == inputs; combine back with weights 1/k
    y_sorted_unsort = jnp.zeros_like(xs).at[plan.sort_idx].set(xs)
    idx = jnp.arange(T * k, dtype=jnp.int32).reshape(T, k)
    y = ops.combine_tokens(y_sorted_unsort, idx, jnp.full((T, k), 1.0 / k))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5)
