"""Placement subsystem unit tests: greedy placer, planner/cost model,
migrate round-trips, and the local (single-worker) index-table path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.monitor import LoadMonitor, expert_placement
from repro.placement import (ExpertPlacement, PlacementController,
                             from_logical, identity_placement, migrate,
                             placement_cost, plan_placement,
                             router_index_table, shadow_spec, to_logical)


def _zipf(E, a=1.2):
    load = 1.0 / (np.arange(E) + 1) ** a
    return load / load.sum()


# ---------------------------------------------------------------------------
# Greedy placer (core/monitor.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,W", [(8, 4), (10, 4), (7, 3), (5, 8), (16, 16)])
def test_greedy_placer_places_every_expert(E, W):
    place = expert_placement(E, W, np.random.RandomState(0).rand(E))
    assert len(place) == E
    counts = np.bincount(place, minlength=W)
    # remainder spread: worker expert counts differ by at most 1
    assert counts.max() - counts.min() <= 1
    assert counts.sum() == E


def test_greedy_placer_balances_load_sums():
    E, W = 12, 4
    load = _zipf(E)
    place = np.asarray(expert_placement(E, W, load))
    sums = np.asarray([load[place == w].sum() for w in range(W)])
    naive = np.asarray([load[w * 3:(w + 1) * 3].sum() for w in range(W)])
    assert sums.max() < naive.max() - 1e-9  # beats contiguous blocks
    # within 10% of the count-constrained optimum (hottest + 2 lightest)
    lower = load[0] + load[-2:].sum()
    assert sums.max() <= lower * 1.1


def test_greedy_placer_remainder_not_dumped_on_worker0():
    # seed bug: E % W experts all silently defaulted to worker 0
    E, W = 9, 4
    place = np.asarray(expert_placement(E, W, np.ones(E)))
    assert np.bincount(place, minlength=W).max() == 3


# ---------------------------------------------------------------------------
# Plans + cost model
# ---------------------------------------------------------------------------


def test_identity_placement_is_identity():
    p = identity_placement(8, 4)
    assert p.is_identity
    assert list(p.logical_to_physical) == list(range(8))
    assert list(p.expert_to_rank) == [0, 0, 1, 1, 2, 2, 3, 3]
    assert p.replication.tolist() == [1] * 8


def test_plan_is_valid_permutation_and_shadow_geometry():
    E, W = 16, 4
    plan = plan_placement(_zipf(E), W, d_model=256, d_hidden=512,
                          capacity=4096)
    assert sorted(plan.physical_to_logical) == list(range(E))
    assert plan.num_shadow % W == 0
    assert 0 < plan.num_owned <= E and plan.num_owned % W == 0
    assert 0.0 < plan.capacity_scale <= 1.0
    # the shadowed experts are the hottest ones
    if plan.num_shadow:
        shadowed = set(plan.physical_to_logical[plan.num_owned:])
        assert shadowed == set(range(plan.num_shadow))
        assert (plan.replication == np.where(
            plan.expert_to_rank < 0, W, 1)).all()


def test_planner_shadows_when_comm_dominates():
    # huge token buffers vs tiny experts: shadowing must pay
    plan = plan_placement(_zipf(16), 4, d_model=256, d_hidden=512,
                          capacity=4096)
    assert plan.num_shadow > 0
    assert plan.capacity_scale < 1.0


def test_planner_declines_when_weight_sync_dominates():
    # big experts, small buffers: replication costs more than the a2a saves
    plan = plan_placement(_zipf(16), 4, d_model=1024, d_hidden=8192,
                          capacity=64)
    assert plan.num_shadow == 0


def test_cost_model_improves_and_never_raises_drops():
    E, W = 16, 4
    load = _zipf(E)
    kw = dict(d_model=256, d_hidden=512, capacity=4096)
    plan = plan_placement(load, W, **kw)
    base = placement_cost(identity_placement(E, W), load, **kw)
    new = placement_cost(plan, load, **kw)
    assert new.total_s < base.total_s
    assert new.drop_frac <= base.drop_frac + 1e-9


def test_plan_rejects_indivisible_ranks():
    with pytest.raises(ValueError):
        plan_placement(_zipf(10), 4, d_model=8, d_hidden=8, capacity=8)


def test_shadow_spec_geometry():
    plan = ExpertPlacement(8, 4, tuple(range(8)), num_shadow=4,
                           capacity_scale=0.5)
    spec = shadow_spec(plan, 8, 64)
    assert spec.num_owned == 4 and spec.num_shadow == 4
    assert spec.main_capacity == 32 and spec.shadow_capacity == 64
    assert spec.width == 64
    assert spec.capacities.tolist() == [32] * 4 + [64] * 4
    assert spec.a2a_elems(16) == 4 * 32 * 16


# ---------------------------------------------------------------------------
# Migration
# ---------------------------------------------------------------------------


CFG = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32, capacity_factor=8.0)


@pytest.fixture(scope="module")
def layer():
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    return params, x


def _some_plan(E=8, W=4, S=0):
    load = _zipf(E)
    hot = np.argsort(-load)
    phys = tuple(int(e) for e in np.sort(hot[S:])) + tuple(
        int(e) for e in hot[:S])
    return ExpertPlacement(E, W, phys, num_shadow=S)


def test_migrate_round_trip_bitwise(layer):
    params, _ = layer
    plan = _some_plan(S=4)
    back = to_logical(from_logical(params, plan), plan)
    for k, v in params["experts"].items():
        np.testing.assert_array_equal(np.asarray(back["experts"][k]),
                                      np.asarray(v))


def test_migrate_between_plans(layer):
    params, _ = layer
    a, b = _some_plan(S=0), _some_plan(S=4)
    via = migrate(from_logical(params, a), a, b)
    direct = from_logical(params, b)
    for k in params["experts"]:
        np.testing.assert_array_equal(np.asarray(via["experts"][k]),
                                      np.asarray(direct["experts"][k]))


def test_migrate_stacked_lm_tree_and_opt_state():
    from repro.optim import AdamW
    E = 8
    tree = {"layers": {"ffn": {"experts": {
        "wi_gate": jnp.arange(3 * E * 2 * 4, dtype=jnp.float32).reshape(3, E, 2, 4)}},
        "attn": {"w": jnp.ones((3, 4, 4))}}}
    plan = _some_plan()
    opt = AdamW()
    state = opt.init(tree)
    phys = from_logical(tree, plan)
    sphys = from_logical(state, plan)
    perm = np.asarray(plan.physical_to_logical)
    got = np.asarray(phys["layers"]["ffn"]["experts"]["wi_gate"])
    want = np.asarray(tree["layers"]["ffn"]["experts"]["wi_gate"])[:, perm]
    np.testing.assert_array_equal(got, want)
    # non-expert leaves untouched; optimizer mirrors the param permutation
    np.testing.assert_array_equal(np.asarray(phys["layers"]["attn"]["w"]),
                                  np.asarray(tree["layers"]["attn"]["w"]))
    np.testing.assert_array_equal(
        np.asarray(sphys.mu["layers"]["ffn"]["experts"]["wi_gate"]),
        np.asarray(state.mu["layers"]["ffn"]["experts"]["wi_gate"])[:, perm])


def test_local_path_with_index_table_matches_bitwise(layer):
    """Migrated params + remapped router == original outputs, bitwise."""
    params, x = layer
    plan = _some_plan(S=4)
    y0, m0 = fmoe.fmoe_apply(params, x, CFG)
    y1, m1 = fmoe.fmoe_apply(from_logical(params, plan), x, CFG,
                             dist=fmoe.DistConfig.local(placement=plan))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(m0.load), np.asarray(m1.load))
    table = router_index_table(plan)
    assert sorted(table.tolist()) == list(range(8))


def test_local_ragged_path_with_placement(layer):
    import dataclasses
    params, x = layer
    cfg = dataclasses.replace(CFG, dispatch="ragged")
    plan = _some_plan(S=0)
    y0, _ = fmoe.fmoe_apply(params, x, cfg)
    y1, _ = fmoe.fmoe_apply(from_logical(params, plan), x, cfg,
                            dist=fmoe.DistConfig.local(placement=plan))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------


def test_controller_replans_on_cadence_with_skew():
    from repro.core.balance import MoEMetrics
    mon = LoadMonitor(16, ema=0.5)
    ctl = PlacementController(mon, 4, d_model=256, d_hidden=512,
                              capacity=4096, every=4)
    skew = _zipf(16)
    fired = []
    for s in range(12):
        mon.update(MoEMetrics(0.0, 0.0, skew, 0.0))
        out = ctl.maybe_replan(s)
        if out is not None:
            fired.append(s)
    assert fired and fired[0] == 4
    assert all(f % 4 == 0 for f in fired)
    assert ctl.current.num_shadow > 0  # comm-dominated regime shadows


def test_controller_idles_on_balanced_load():
    # weight-sync-dominated regime: neither shadowing nor permuting can beat
    # identity under uniform load, so the controller must never migrate
    from repro.core.balance import MoEMetrics
    mon = LoadMonitor(16, ema=0.5)
    ctl = PlacementController(mon, 4, d_model=1024, d_hidden=8192,
                              capacity=64, every=2)
    for s in range(8):
        mon.update(MoEMetrics(0.0, 0.0, np.full(16, 1 / 16.0), 0.0))
        assert ctl.maybe_replan(s) is None
    assert ctl.current.is_identity
