"""Optimizer, data pipeline, checkpoint, balance-loss, fmoefy tests."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.core.balance import load_balance_loss, router_z_loss
from repro.core.fmoefy import fmoefy
from repro.data import ByteTokenizer, SyntheticLM
from repro.optim import AdamW, global_norm, warmup_cosine


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _quad_params():
    return {"a": jnp.array([2.0, -3.0]), "b": {"c": jnp.array([[1.5]])}}


def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = _quad_params()
    state = opt.init(params)
    loss = lambda p: sum(jnp.sum(l ** 2) for l in jax.tree.leaves(p))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_adamw_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, gnorm = opt.update(huge, state, params)
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_adamw_bf16_moments():
    opt = AdamW(moment_dtype="bfloat16")
    params = _quad_params()
    state = opt.init(params)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state.mu))
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2, _ = opt.update(g, state, params)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(s2.nu))


def test_schedule_monotone_warmup():
    xs = [float(warmup_cosine(s, warmup=10, total=100)) for s in range(10)]
    assert all(b >= a for a, b in zip(xs, xs[1:]))
    assert float(warmup_cosine(100, warmup=10, total=100)) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# Balance losses
# ---------------------------------------------------------------------------


def test_balance_loss_minimized_at_uniform():
    E, T = 4, 1000
    probs = jnp.full((T, E), 1.0 / E)
    ids = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], axis=1)
    uniform = float(load_balance_loss(probs, ids.astype(jnp.int32), E))
    assert uniform == pytest.approx(1.0, rel=1e-3)
    # concentrated routing scores worse
    ids_bad = jnp.zeros((T, 2), jnp.int32)
    probs_bad = jnp.zeros((T, E)).at[:, 0].set(1.0)
    assert float(load_balance_loss(probs_bad, ids_bad, E)) > uniform


def test_z_loss_penalizes_large_logits():
    small = router_z_loss(jnp.ones((8, 4)) * 0.1)
    big = router_z_loss(jnp.ones((8, 4)) * 10.0)
    assert float(big) > float(small)


# ---------------------------------------------------------------------------
# fmoefy (paper Listing 1)
# ---------------------------------------------------------------------------


def test_fmoefy_keeps_active_flops():
    cfg = get_config("smollm-360m")
    moe_cfg = fmoefy(cfg, num_experts=16, top_k=2)
    assert moe_cfg.moe.num_experts == 16
    # active params ~== dense params (d_h halved for top-2, paper §5.4)
    dense, active = cfg.param_count(), moe_cfg.active_param_count()
    assert abs(active - dense) / dense < 0.05
    # total params grew by roughly E/k
    assert moe_cfg.param_count() > 4 * dense


def test_fmoefy_rejects_double_moe():
    with pytest.raises(ValueError):
        fmoefy(get_config("arctic-480b"))


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([4, 8, 96]), k=st.integers(1, 4))
def test_fmoefy_property(E, k):
    cfg = get_config("granite-3-2b")
    out = fmoefy(cfg, num_experts=E, top_k=k)
    assert out.moe.d_expert_hidden == max(8, cfg.d_ff // k)
    assert out.name.endswith(f"moe{E}")


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------


def test_synthetic_reproducible_and_sharded():
    d1 = SyntheticLM(1000, 32, seed=7)
    d2 = SyntheticLM(1000, 32, seed=7)
    np.testing.assert_array_equal(d1.sample_batch(4), d2.sample_batch(4))
    # host sharding covers the global batch disjointly
    d3 = SyntheticLM(1000, 32, seed=9)
    d4 = SyntheticLM(1000, 32, seed=9)
    b0 = next(d3.batches(8, host_id=0, num_hosts=2))["tokens"]
    b1 = next(d4.batches(8, host_id=1, num_hosts=2))["tokens"]
    assert b0.shape == b1.shape == (4, 32)
    assert not np.array_equal(b0, b1)


def test_synthetic_has_learnable_structure():
    """Markov overlay: successor tokens are predictable above chance."""
    d = SyntheticLM(500, 256, seed=0, markov_weight=0.9)
    toks = d.sample_batch(8)
    hits = 0
    total = 0
    for row in toks:
        for t in range(len(row) - 1):
            total += 1
            hits += int(row[t + 1] in d.succ[row[t]])
    assert hits / total > 0.5  # vs ~4/500 by chance


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello FastMoE"
    assert tok.decode(tok.encode(s)) == s


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    save(str(tmp_path / "ck"), tree, step=7)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = restore(str(tmp_path / "ck"), like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, out)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), {"w": jnp.ones((3, 3))})


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save(str(tmp_path / "ck"), {"w": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore(str(tmp_path / "ck"), {"w": jnp.ones(2), "extra": jnp.ones(1)})
