"""Optional-hypothesis shim: property tests skip cleanly when the library is
absent instead of killing collection with ModuleNotFoundError.

Usage in test modules::

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects; without it, ``given``
decorates the test into a pytest skip and ``st.*`` return inert placeholders
(strategies are only ever built at decoration time, never drawn from).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

    def settings(*args, **kwargs):
        if args and callable(args[0]):  # bare @settings
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
