"""Smart-schedule overlap tests (repro/core/pipeline.py): the chunked,
ppermute-decomposed exchange must be *bit-exact* vs the serial all-to-all,
composed with shadow placement, expert-internal TP and the bf16 wire.

Multi-device cases run in subprocesses with fake host devices via the
consolidated harness in tests/dist_utils.py (the main process keeps its
single CPU device).
"""
import dist_utils as du
from repro.core.pipeline import resolve_chunks


def test_resolve_chunks():
    assert resolve_chunks(0, 64) == 1
    assert resolve_chunks(1, 64) == 1
    assert resolve_chunks(4, 64) == 4
    assert resolve_chunks(3, 64) == 2  # nearest feasible divisor below
    assert resolve_chunks(5, 64) == 4
    assert resolve_chunks(100, 64) == 64  # capped at capacity
    assert resolve_chunks(7, 7) == 7


def test_moe_dist_threads_overlap_options():
    """launch.train.moe_dist must carry overlap_chunks/wire_dtype into the
    a2a DistConfig (and only there — psum fallbacks have no exchange)."""
    import jax
    from repro.configs import get_config, reduced
    from repro.launch.train import moe_dist

    cfg = reduced(get_config("fastmoe-gpt"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    dist = moe_dist(cfg, mesh, 64,
                    opts={"overlap_chunks": 4, "wire_dtype": "bf16"})
    assert dist.overlap_chunks == 4 and dist.wire_dtype == "bf16"
    assert dist.mode == "a2a"
    dist = moe_dist(cfg, mesh, 64, opts={})
    assert dist.overlap_chunks == 0 and dist.wire_dtype is None


_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.core import fmoe
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=64,
                    capacity_factor=8.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
    def apply(dist, p=None):
        with mesh:
            return jax.jit(lambda p_, x_: fmoe.fmoe_apply(p_, x_, cfg,
                                                          dist=dist))(p or params, x)
    y0, m0 = apply(fmoe.DistConfig(mesh, ("data", "model")))
"""


def test_ppermute_a2a_equals_lax_all_to_all():
    """The decomposed exchange is pure data movement: bitwise equal to
    lax.all_to_all for single and tuple mesh axes, f32 and bf16."""
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core.pipeline import chunked_all_to_all, ppermute_all_to_all

    for shape, axes in [((4,), ("model",)), ((2, 2), ("pod", "model"))]:
        mesh = jax.make_mesh(shape, axes)
        ax = axes[0] if len(axes) == 1 else axes
        mp = 4
        x = jnp.arange(4 * 4 * 6 * 5, dtype=jnp.float32).reshape(4 * 4, 6, 5)
        spec = P(ax, None, None)
        ref = compat.shard_map(
            lambda b: jax.lax.all_to_all(b, ax, 0, 0, tiled=True),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        pp = compat.shard_map(
            lambda b: ppermute_all_to_all(b, ax, mp),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        ck = compat.shard_map(
            lambda b: chunked_all_to_all(b, ax, mp, 3),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        with mesh:
            np.testing.assert_array_equal(np.asarray(ref(x)), np.asarray(pp(x)))
            np.testing.assert_array_equal(np.asarray(ref(x)), np.asarray(ck(x)))
        # wire cast round-trips through bf16 exactly for bf16 payloads
        xb = x.astype(jnp.bfloat16)
        ppb = compat.shard_map(
            lambda b: ppermute_all_to_all(b, ax, mp, wire_dtype=jnp.bfloat16),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        refb = compat.shard_map(
            lambda b: jax.lax.all_to_all(b, ax, 0, 0, tiled=True),
            mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
        with mesh:
            np.testing.assert_array_equal(np.asarray(refb(xb)), np.asarray(ppb(xb)))
    print("ppermute a2a ok")
    """)
    assert "ppermute a2a ok" in out


def test_chunked_moe_bit_exact_vs_serial():
    """Acceptance: the pipelined path (any chunking, incl. non-dividing
    requests) returns bit-identical outputs, metrics and gradients."""
    out = du.run(_SETUP + """
    def loss(p, dist):
        y, m = fmoe.fmoe_apply(p, x, cfg, dist=dist)
        return (y ** 2).mean() + 0.01 * m.aux_loss
    with mesh:
        g0 = jax.jit(lambda p: jax.grad(loss)(p, fmoe.DistConfig(mesh, ("data", "model"))))(params)
    for nc in (2, 4, 3, 16):
        dist = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=nc)
        y1, m1 = apply(dist)
        assert (np.asarray(y0) == np.asarray(y1)).all(), nc
        np.testing.assert_array_equal(np.asarray(m0.load), np.asarray(m1.load))
    dist = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=4)
    with mesh:
        g1 = jax.jit(lambda p: jax.grad(loss)(p, dist))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # the pipelined schedule lowers to async-schedulable collective-permutes
    with mesh:
        txt = jax.jit(lambda p, x_: fmoe.fmoe_apply(p, x_, cfg, dist=dist)[0]
                      ).lower(params, x).compile().as_text()
    assert "collective-permute" in txt
    print("chunked bit-exact ok")
    """)
    assert "chunked bit-exact ok" in out


def test_chunked_composes_with_shadow_and_tp():
    """overlap_chunks must compose with placement/shadowing (shadow compute
    as overlap filler) and with expert-internal TP."""
    out = du.run(_SETUP + """
    from repro.placement import ExpertPlacement, from_logical
    load = np.asarray(m0.load)
    hot = np.argsort(-load)
    S = 4
    phys = tuple(int(e) for e in np.sort(hot[S:])) + tuple(int(e) for e in hot[:S])
    plan = ExpertPlacement(8, 4, phys, num_shadow=S, capacity_scale=1.0)
    pp = from_logical(params, plan)
    for nc in (0, 4):
        dist = fmoe.DistConfig(mesh, ("data", "model"), placement=plan,
                               overlap_chunks=nc)
        y1, m1 = apply(dist, pp)
        assert float(jnp.abs(y1 - y0).max()) < 1e-5, nc
        np.testing.assert_allclose(np.asarray(m1.load), load, atol=1e-6)
    yt0, _ = apply(fmoe.DistConfig(mesh, ("data", "model"), tp_axis="data"))
    yt1, _ = apply(fmoe.DistConfig(mesh, ("data", "model"), tp_axis="data",
                                   overlap_chunks=4))
    assert (np.asarray(yt0) == np.asarray(yt1)).all()
    print("shadow+tp compose ok")
    """)
    assert "shadow+tp compose ok" in out


def test_wire_dtype_bf16_round_trip_tolerance():
    """Satellite: DistConfig.wire_dtype="bf16" halves payload bytes; the
    round-trip must stay within bf16 quantization of the f32 path and be
    bit-exact between serial and chunked schedules."""
    out = du.run(_SETUP + """
    ys = {}
    for nc in (0, 4):
        dist = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=nc,
                               wire_dtype="bf16")
        ys[nc], _ = apply(dist)
        # bf16 has 8 mantissa bits: payload error ~2^-8 relative, amplified
        # a little by the combine weights
        err = float(jnp.abs(ys[nc] - y0).max())
        assert err < 0.05, (nc, err)
        assert err > 0  # the cast really happened
    assert (np.asarray(ys[0]) == np.asarray(ys[4])).all()
    # program structure: the payload exchange really runs at bf16.  (The
    # compiled-HLO byte count is backend-dependent — XLA:CPU commutes the
    # widening convert across the collective — so check the traced program,
    # where the wire dtype is what _moe_a2a asked for.)
    dist = fmoe.DistConfig(mesh, ("data", "model"), wire_dtype="bf16")
    with mesh:
        jaxpr = str(jax.make_jaxpr(
            lambda p, x_: fmoe.fmoe_apply(p, x_, cfg, dist=dist)[0])(params, x))
    assert "all_to_all" in jaxpr and "bf16" in jaxpr
    dist32 = fmoe.DistConfig(mesh, ("data", "model"))
    with mesh:
        jaxpr32 = str(jax.make_jaxpr(
            lambda p, x_: fmoe.fmoe_apply(p, x_, cfg, dist=dist32)[0])(params, x))
    assert "bf16" not in jaxpr32
    print("wire dtype ok")
    """)
    assert "wire dtype ok" in out
