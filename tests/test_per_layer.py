"""Per-layer placement unit + property tests (ISSUE 5) — host-side, tier 1.

Covers the stacked-plan type (shared-geometry validation), the per-layer
planner (degeneracy to the shared planner under identical loads, distinct
layouts under skew), per-layer migration (hypothesis round-trips), the
logical->physical table inverse, the (L, E) LoadMonitor, the per-layer
controller, and layout-free checkpoints under per-layer plans.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.balance import MoEMetrics
from repro.core.monitor import LoadMonitor
from repro.placement import (ExpertPlacement, PerLayerPlacement,
                             PlacementController, from_logical,
                             identity_per_layer, migrate, per_layer_cost,
                             per_layer_placement, placement_cost,
                             plan_placement, plan_placement_per_layer,
                             router_index_table, to_logical)


def _zipf(E, a=1.2):
    load = 1.0 / (np.arange(E) + 1) ** a
    return load / load.sum()


def _random_plan(E, W, S, seed):
    """A structurally valid plan with a random permutation + shadow set."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(E)
    phys = tuple(int(e) for e in np.r_[np.sort(perm[S:]), perm[:S]])
    return ExpertPlacement(E, W, phys, num_shadow=S)


def _random_per_layer(L, E, W, S, seed):
    return per_layer_placement(
        [_random_plan(E, W, S, seed * 101 + i) for i in range(L)])


# ---------------------------------------------------------------------------
# Type / planner
# ---------------------------------------------------------------------------


def test_per_layer_validates_shared_geometry():
    a = _random_plan(8, 4, 4, 0)
    b = _random_plan(8, 4, 0, 1)  # different shadow count
    with pytest.raises(ValueError):
        per_layer_placement([a, b])
    plp = per_layer_placement([a, _random_plan(8, 4, 4, 1)])
    assert plp.num_layers == 2 and plp.num_shadow == 4
    assert plp.geometry == a
    assert plp.logical_to_physical.shape == (2, 8)


def test_identity_per_layer_is_identity():
    plp = identity_per_layer(8, 4, 3)
    assert plp.is_identity and plp.num_layers == 3
    np.testing.assert_array_equal(plp.logical_to_physical,
                                  np.tile(np.arange(8), (3, 1)))


def test_planner_degenerates_to_shared_on_identical_rows():
    E, W, L = 16, 4, 3
    row = _zipf(E)
    kw = dict(d_model=256, d_hidden=512, capacity=4096)
    plp = plan_placement_per_layer(np.stack([row] * L), W, **kw)
    shared = plan_placement(row, W, **kw)
    assert all(p == shared for p in plp.layers)


def test_planner_distinct_layouts_under_skew():
    E, W, L = 16, 4, 4
    rng = np.random.default_rng(0)
    load = np.stack([_zipf(E)[rng.permutation(E)] for _ in range(L)])
    plp = plan_placement_per_layer(load, W, d_model=256, d_hidden=512,
                                   capacity=4096)
    plp.validate()  # geometry shared by construction
    assert len({p.physical_to_logical for p in plp.layers}) >= 2
    # each layer shadows its OWN hottest experts
    if plp.num_shadow:
        for i, p in enumerate(plp.layers):
            hottest = set(np.argsort(-load[i])[:plp.num_shadow].tolist())
            assert set(p.physical_to_logical[p.num_owned:]) == hottest


def test_per_layer_cost_sums_layers():
    E, W, L = 16, 4, 2
    load = np.stack([_zipf(E)] * L)
    plp = identity_per_layer(E, W, L)
    kw = dict(d_model=256, d_hidden=512, capacity=4096)
    total = per_layer_cost(plp, load, **kw)
    single = placement_cost(plp.layer(0), load[0], **kw)
    assert total.total_s == pytest.approx(L * single.total_s)


def test_planner_rejects_bad_shapes():
    with pytest.raises(ValueError):
        plan_placement_per_layer(_zipf(8), 4, d_model=8, d_hidden=8,
                                 capacity=8)  # 1-D load
    with pytest.raises(ValueError):
        plan_placement_per_layer(np.stack([_zipf(10)] * 2), 4, d_model=8,
                                 d_hidden=8, capacity=8)  # E % ranks


# ---------------------------------------------------------------------------
# Property tests: migrate round-trips + table inverses (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2**31 - 1), st.integers(0, 2))
def test_migrate_round_trip_identity_per_layer(L, seed, s_idx):
    """old -> new -> old is the identity on every layer's expert slice."""
    E, W = 8, 4
    S = (0, 4, 8 // 2)[s_idx] // W * W
    old = _random_per_layer(L, E, W, S, seed % 10_000)
    new = _random_per_layer(L, E, W, S, seed % 10_000 + 7)
    tree = {"layers": {"ffn": {"experts": {
        "wi": jnp.arange(L * E * 2 * 3, dtype=jnp.float32).reshape(L, E, 2, 3)}}}}
    there = migrate(tree, old, new)
    back = migrate(there, new, old)
    np.testing.assert_array_equal(
        np.asarray(back["layers"]["ffn"]["experts"]["wi"]),
        np.asarray(tree["layers"]["ffn"]["experts"]["wi"]))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_logical_physical_tables_are_inverse(L, seed):
    plp = _random_per_layer(L, 8, 4, 4, seed % 10_000)
    l2p = plp.logical_to_physical  # (L, E)
    p2l = plp.physical_to_logical
    eye = np.tile(np.arange(8), (L, 1))
    np.testing.assert_array_equal(np.take_along_axis(l2p, p2l, 1), eye)
    np.testing.assert_array_equal(np.take_along_axis(p2l, l2p, 1), eye)
    np.testing.assert_array_equal(router_index_table(plp), l2p)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_from_to_logical_round_trip(L, seed):
    plp = _random_per_layer(L, 8, 4, 0, seed % 10_000)
    tree = {"layers": {"ffn": {"experts": {
        "wo": jnp.arange(L * 8 * 3 * 2, dtype=jnp.float32).reshape(L, 8, 3, 2)}},
        "attn": {"w": jnp.ones((L, 4, 4))}}}
    back = to_logical(from_logical(tree, plp), plp)
    np.testing.assert_array_equal(
        np.asarray(back["layers"]["ffn"]["experts"]["wo"]),
        np.asarray(tree["layers"]["ffn"]["experts"]["wo"]))
    # non-expert leaves untouched
    np.testing.assert_array_equal(
        np.asarray(from_logical(tree, plp)["layers"]["attn"]["w"]),
        np.asarray(tree["layers"]["attn"]["w"]))


def test_per_layer_plan_rejects_unstacked_tree():
    plp = _random_per_layer(2, 8, 4, 0, 0)
    layer = {"experts": {"wi": jnp.zeros((8, 4, 4))}}  # bare (E, ...) leaf
    with pytest.raises(ValueError):
        from_logical(layer, plp)


def test_migrate_mixed_shared_and_per_layer():
    L, E, W = 3, 8, 4
    shared = _random_plan(E, W, 0, 5)
    plp = _random_per_layer(L, E, W, 0, 6)
    tree = {"experts": {"wi": jnp.arange(L * E * 2, dtype=jnp.float32)
                        .reshape(L, E, 2, 1)}}
    via = migrate(from_logical(tree, shared), shared, plp)
    direct = from_logical(tree, plp)
    np.testing.assert_array_equal(np.asarray(via["experts"]["wi"]),
                                  np.asarray(direct["experts"]["wi"]))


# ---------------------------------------------------------------------------
# Monitor + controller
# ---------------------------------------------------------------------------


def test_monitor_tracks_layer_loads():
    mon = LoadMonitor(8, ema=0.5, num_layers=2)
    load = np.stack([_zipf(8), _zipf(8)[::-1]])
    for _ in range(6):
        mon.update(MoEMetrics(0.0, 0.0, load, 0.0))
    assert mon.load_ema_layers.shape == (2, 8)
    # converges toward the per-layer distributions, summed EMA toward mean
    np.testing.assert_allclose(mon.load_ema_layers[0], _zipf(8), atol=0.05)
    np.testing.assert_allclose(mon.load_ema_layers[1], _zipf(8)[::-1],
                               atol=0.05)
    with pytest.raises(ValueError):
        mon.update(MoEMetrics(0.0, 0.0, np.ones((3, 8)), 0.0))


def test_controller_per_layer_replans_with_skew():
    L = 3
    mon = LoadMonitor(16, ema=0.5, num_layers=L)
    ctl = PlacementController(mon, 4, d_model=256, d_hidden=512,
                              capacity=4096, every=4, num_layers=L)
    rng = np.random.default_rng(0)
    skew = np.stack([_zipf(16)[rng.permutation(16)] for _ in range(L)])
    fired = []
    for s in range(12):
        mon.update(MoEMetrics(0.0, 0.0, skew, 0.0))
        out = ctl.maybe_replan(s)
        if out is not None:
            fired.append(s)
            assert isinstance(out, PerLayerPlacement)
    assert fired and fired[0] == 4
    assert ctl.current.num_shadow > 0  # comm-dominated regime shadows


def test_controller_per_layer_requires_layer_monitor():
    mon = LoadMonitor(16)  # no layer EMA
    with pytest.raises(ValueError):
        PlacementController(mon, 4, d_model=8, d_hidden=8, capacity=8,
                            num_layers=2)


# ---------------------------------------------------------------------------
# fmoe guards + layout-free checkpoints
# ---------------------------------------------------------------------------


def test_fmoe_apply_rejects_whole_per_layer_plan():
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=16)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jnp.zeros((4, 16))
    with pytest.raises(TypeError):
        fmoe.fmoe_apply(params, x, cfg,
                        dist=fmoe.DistConfig.local(
                            placement=identity_per_layer(8, 1, 2)))


def test_local_layer_honors_l2p_table():
    """The traced per-layer table path == the static placement path."""
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=16,
                    capacity_factor=8.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    plan = _random_plan(8, 1, 0, 3)
    pp = from_logical(params, plan)
    y0, m0 = fmoe.fmoe_apply(params, x, cfg)
    y1, m1 = fmoe.fmoe_apply(pp, x, cfg,
                             dist=fmoe.DistConfig.local(placement=plan))
    y2, m2 = jax.jit(lambda p, x, t: fmoe.fmoe_apply(p, x, cfg, l2p=t))(
        pp, x, jnp.asarray(plan.logical_to_physical))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(m1.load), np.asarray(m2.load))


def test_checkpoint_layout_free_under_per_layer_plan(tmp_path):
    """save(placement=A) then restore(placement=B) == migrate(A -> B):
    checkpoints never know the physical layout."""
    from repro.checkpoint import ckpt

    L, E = 2, 8
    tree = {"layers": {"ffn": {"experts": {
        "wi": jnp.arange(L * E * 4, dtype=jnp.float32).reshape(L, E, 2, 2)}}}}
    a = _random_per_layer(L, E, 4, 4, 11)
    b = _random_per_layer(L, E, 4, 4, 22)
    phys_a = from_logical(tree, a)
    path = os.path.join(str(tmp_path), "step_1")
    ckpt.save(path, phys_a, placement=a)
    got_b = ckpt.restore(path, tree, placement=b)
    want_b = from_logical(tree, b)
    np.testing.assert_array_equal(
        np.asarray(got_b["layers"]["ffn"]["experts"]["wi"]),
        np.asarray(want_b["layers"]["ffn"]["experts"]["wi"]))
    # and a plain restore comes back in logical order
    got = ckpt.restore(path, tree)
    np.testing.assert_array_equal(
        np.asarray(got["layers"]["ffn"]["experts"]["wi"]),
        np.asarray(tree["layers"]["ffn"]["experts"]["wi"]))
