"""Routing-zoo end-to-end tests (ISSUE 10 tentpole acceptance).

Every router in ``tests/dist_utils.ROUTERS`` must pass the same differential
sweep as the baseline top-k gate — bit-exact forward vs its single-rank
oracle on capacity AND ragged dispatch, with shadowing and overlap enabled,
grads included (no parallel test plumbing: the routers ride the existing
dist_utils oracle/assertion helpers as a new sweep axis).

Beyond the sweep:
* expert-choice gets a dense == dispatched differential (the second client
  of the dropless/ragged machinery), grads included;
* shared experts are proven absent from the exchange — device-side wire
  counters AND compiled-HLO all-to-all bytes unchanged vs a routed-only
  baseline of equal routed width;
* the DeepSeek-V2 config (shared + routed experts, MLA) runs a train step
  and a decode step end to end on a 1x4 mesh;
* expert-choice's by-construction flat load is recognized by the placement
  controller as a no-replan signal.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import dist_utils as du


# ---------------------------------------------------------------------------
# The router sweep: dispatch x {plain, shadow+overlap} vs single-rank oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router", du.ROUTERS)
def test_router_sweep_bit_exact_1x4(router):
    """Acceptance: every router, on both dispatch modes, reproduces its
    single-rank oracle bit-exactly on the 1x4 fused path — plain AND with
    shadowed hot experts + overlap chunking — including grads.

    Expert-choice routes per token shard under a2a (each rank's experts
    pick from the tokens that rank holds), so its oracle is the shard-wise
    local apply (dist_utils.oracle_sharded); every other router's routing
    is per-token and the plain oracle applies.  Grads use the aux-free loss
    (the sharded balance loss is a different function than the global one)
    and shadowed grads compare through the plan's physical permutation."""
    out = du.run(f"""
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    from repro.placement import from_logical
    router = {router!r}
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    for dispatch in ("capacity", "ragged"):
        env = du.moe_env(dispatch=dispatch, router=router)
        if router == "expert_choice":
            y_ref, load_ref = du.oracle_sharded(env, 4, impl="fused")
        else:
            y_ref, m_ref = du.oracle(env, impl="fused")
            load_ref = m_ref.load
        dist0 = fmoe.DistConfig(mesh, ("data", "model"))
        y0, m0 = du.dist_apply(env, mesh, dist0, impl="fused")
        du.assert_bit_exact(y0, y_ref, msg=(dispatch, "plain"))
        np.testing.assert_allclose(np.asarray(m0.load),
                                   np.asarray(load_ref), atol=1e-6)
        # shadowing + overlap: same oracle, still bitwise
        pl = du.hot_shadow_plan(np.asarray(m0.load), 4, 4)
        pp = from_logical(env.params, pl)
        dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl,
                               overlap_chunks=2)
        y1, m1 = du.dist_apply(env, mesh, dist, params=pp, impl="fused")
        du.assert_bit_exact(y1, y_ref, msg=(dispatch, "shadow"))
        assert float(m1.drop_frac) == 0.0, (dispatch, "shadow drops")
        if router == "expert_choice":
            E = env.cfg.num_experts
            np.testing.assert_allclose(np.asarray(m1.load), 1.0 / E,
                                       atol=1e-6)  # flat by construction
            xs = env.x.reshape(-1, env.x.shape[-1])
            xs = xs.reshape(4, -1, env.x.shape[-1])
            def loss_ref(p):
                tot = 0.0
                for i in range(4):
                    y, _ = fmoe.fmoe_apply(p, xs[i], env.cfg, impl="fused")
                    tot = tot + (y ** 2).sum()
                return tot / env.x.size
            g_ref = jax.jit(jax.grad(loss_ref))(env.params)
        else:
            g_ref = du.layer_grads(env, None, impl="fused", aux_weight=0.0)
        if dispatch == "ragged":
            g_plain = du.layer_grads(env, dist0, mesh=mesh, impl="fused",
                                     aux_weight=0.0)
            du.assert_grads_match(g_ref, g_plain,
                                  bitwise_experts=router != "expert_choice")
        g_sh = du.layer_grads(env, dist, mesh=mesh, params=pp, impl="fused",
                              aux_weight=0.0)
        perm = jnp.asarray(list(pl.physical_to_logical))
        g_ref_p = {{**g_ref, "experts": {{k: v[perm] for k, v in
                                          g_ref["experts"].items()}}}}
        du.assert_grads_match(g_ref_p, g_sh, bitwise_experts=False)
    print("router sweep ok")
    """, devices=4)
    assert "router sweep ok" in out


# ---------------------------------------------------------------------------
# Expert-choice: dense reference == dispatched (capacity and ragged) + grads
# ---------------------------------------------------------------------------


def test_expert_choice_dense_equals_dispatched():
    """The dense single-worker expert-choice layer (core/gate
    expert_choice_moe) and the dispatched paths must agree: bit-exact on
    every cell except local ragged+einsum (XLA's ragged_dot lowering is
    group-structure-sensitive — the documented psum-docstring exception —
    so that one cell gets an ulp tolerance).  The psum mode on a 1x4 mesh
    replicates tokens over the expert axis, so dispatched global routing
    exactly equals the dense reference — grads included, bitwise."""
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    from repro.core.gate import expert_choice_moe
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    for dispatch in ("capacity", "ragged"):
        for impl in ("einsum", "fused"):
            env = du.moe_env(dispatch=dispatch, router="expert_choice",
                             capacity_factor=2.0)
            xf = env.x.reshape(-1, env.x.shape[-1])
            y_dense, _ = expert_choice_moe(env.params, xf, env.cfg,
                                           capacity_factor=2.0)
            y_loc, m_loc = du.oracle(env, impl=impl)
            if (dispatch, impl) == ("ragged", "einsum"):
                du.assert_close(y_loc.reshape(xf.shape), y_dense, 1e-5)
            else:
                du.assert_bit_exact(y_loc.reshape(xf.shape), y_dense,
                                    msg=(dispatch, impl, "local"))
            assert float(m_loc.drop_frac) == 0.0
            dist = fmoe.DistConfig(mesh, ("data",))
            assert dist.mode == "psum"
            y_ps, m_ps = du.dist_apply(env, mesh, dist, impl=impl)
            du.assert_bit_exact(y_ps.reshape(xf.shape), y_dense,
                                msg=(dispatch, impl, "psum"))
            np.testing.assert_allclose(np.asarray(m_ps.load),
                                       1.0 / env.cfg.num_experts, atol=1e-6)
            assert float(m_ps.drop_frac) == 0.0
            def loss_dense(p):
                y, _ = expert_choice_moe(p, xf, env.cfg, capacity_factor=2.0)
                return (y ** 2).mean()
            g_dense = jax.jit(jax.grad(loss_dense))(env.params)
            g_ps = du.layer_grads(env, dist, mesh=mesh, impl=impl,
                                  aux_weight=0.0)
            du.assert_grads_match(g_dense, g_ps, bitwise_experts=True,
                                  router_atol=1e-9)
    print("ec dense==dispatched ok")
    """, devices=4)
    assert "ec dense==dispatched ok" in out


# ---------------------------------------------------------------------------
# Shared experts: statically shadowed — zero wire traffic, HLO-verified
# ---------------------------------------------------------------------------


def test_shared_experts_absent_from_exchange():
    """Acceptance: with num_shared_experts > 0 the exchange moves exactly
    the bytes of the routed-only baseline of equal routed width — the
    device-side wire counters AND the compiled HLO's all-to-all byte totals
    are unchanged (shared experts replicate on every rank and bypass
    dispatch entirely)."""
    out = du.run("""
    import numpy as np, jax
    import dist_utils as du
    from repro.core import fmoe
    from repro.launch import roofline
    mesh = du.make_mesh()  # (2, 4)
    dist = fmoe.DistConfig(mesh, ("data", "model"))
    for dispatch in ("capacity", "ragged"):
        env0 = du.moe_env(dispatch=dispatch)
        env1 = du.moe_env(dispatch=dispatch, num_shared_experts=1)
        assert "shared" in env1.params and "shared" not in env0.params
        y0, m0 = du.dist_apply(env0, mesh, dist)
        y1, m1 = du.dist_apply(env1, mesh, dist)
        assert float(m0.obs.wire_elems) == float(m1.obs.wire_elems)
        assert float(m0.obs.wire_bytes) == float(m1.obs.wire_bytes)
        # the shared expert contributes compute (outputs differ) ...
        assert float(np.abs(np.asarray(y1) - np.asarray(y0)).max()) > 1e-3
        # ... but zero wire: HLO all-to-all bytes identical
        def a2a_bytes(env):
            with mesh:
                txt = jax.jit(lambda p, x: fmoe.fmoe_apply(
                    p, x, env.cfg, dist=dist)[0]).lower(
                        env.params, env.x).compile().as_text()
            return roofline.collective_bytes(txt).get("all-to-all", 0)
        b0, b1 = a2a_bytes(env0), a2a_bytes(env1)
        assert b0 == b1 and b0 > 0, (dispatch, b0, b1)
    print("shared zero-wire ok")
    """)
    assert "shared zero-wire ok" in out


# ---------------------------------------------------------------------------
# DeepSeek-V2: shared + routed experts end to end (train + decode)
# ---------------------------------------------------------------------------


def test_deepseek_v2_shared_and_routed_train_and_decode():
    """configs/deepseek_v2_236b.py (tiny-ified via reduced()) — MLA
    attention, routed top-k experts AND an always-on shared expert — runs a
    sharded train step and a psum-mode decode step on a 1x4 mesh."""
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import decode_dist
    from repro.launch.train import jit_train_step
    from repro.models import lm
    from repro.optim import AdamW
    cfg = reduced(get_config("deepseek-v2-236b"), num_layers=2, d_model=128)
    assert cfg.moe.num_shared_experts == 1  # reduced keeps a shared expert
    assert cfg.attention.kind == "mla"
    mesh = make_local_mesh(1, 4)
    opt = AdamW()
    B, S = 4, 32
    step_fn, pshard, oshard = jit_train_step(cfg, opt, mesh, B, S)
    params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg),
                            pshard)
    opt_state = jax.device_put(opt.init(params), oshard)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    with mesh:
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.int32(0))
    loss = float(m["loss"])
    assert np.isfinite(loss) and 0 < loss < 20, loss
    dist = decode_dist(cfg, mesh, B)
    assert dist is not None and dist.mode == "psum"
    cache = lm.init_cache(cfg, B, 64)
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0,
                             cfg.vocab_size)
    with mesh:
        logits, cache, dm = jax.jit(lambda p, t, c: lm.decode_step(
            p, cfg, t, jnp.int32(0), c, dist=dist))(params, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    print("deepseek train+decode ok, loss", loss)
    """, devices=4)
    assert "deepseek train+decode ok" in out


# ---------------------------------------------------------------------------
# Flat load is a no-replan signal (expert-choice x placement controller)
# ---------------------------------------------------------------------------


def test_flat_load_skips_replan():
    """Expert-choice produces a perfectly flat load by construction; the
    placement controller must short-circuit the replan tick (no plan+cost
    pass, no migration) instead of proposing a pointless new layout."""
    from repro.core.balance import MoEMetrics
    from repro.core.monitor import LoadMonitor
    from repro.placement import PlacementController

    mon = LoadMonitor(8, ema=0.0)
    ctl = PlacementController(mon, 4, d_model=64, d_hidden=128, capacity=16,
                              every=10)
    mon.update(MoEMetrics(jnp.zeros(()), jnp.zeros(()),
                          jnp.full((8,), 0.125), jnp.zeros(())))
    assert ctl.maybe_replan(10) is None
    assert ctl.flat_skips == 1
    # near-flat within the tolerance still short-circuits
    near = np.full(8, 0.125)
    near[0] += 0.001
    near /= near.sum()
    mon.update(MoEMetrics(jnp.zeros(()), jnp.zeros(()), jnp.asarray(near),
                          jnp.zeros(())))
    assert ctl.maybe_replan(20) is None
    assert ctl.flat_skips == 2
    # a genuinely skewed load passes the gate and reaches the planner
    skew = np.array([0.5, 0.2, 0.1, 0.05, 0.05, 0.04, 0.03, 0.03])
    mon.update(MoEMetrics(jnp.zeros(()), jnp.zeros(()), jnp.asarray(skew),
                          jnp.zeros(())))
    ctl.maybe_replan(30)
    assert ctl.flat_skips == 2  # not flat-skipped


def test_flat_load_skips_replan_per_layer():
    """Per-layer mode: every layer flat => skip; one skewed layer is enough
    to run the planner."""
    from repro.core.balance import MoEMetrics
    from repro.core.monitor import LoadMonitor
    from repro.placement import PlacementController

    L, E = 2, 8
    mon = LoadMonitor(E, num_layers=L, ema=0.0)
    ctl = PlacementController(mon, 4, d_model=64, d_hidden=128, capacity=16,
                              every=10, num_layers=L)
    flat = np.full((L, E), 1.0 / E)
    mon.update(MoEMetrics(jnp.zeros(()), jnp.zeros(()), jnp.asarray(flat),
                          jnp.zeros(())))
    assert ctl.maybe_replan(10) is None
    assert ctl.flat_skips == 1
    skew = flat.copy()
    skew[1] = np.array([0.5, 0.2, 0.1, 0.05, 0.05, 0.04, 0.03, 0.03])
    mon.update(MoEMetrics(jnp.zeros(()), jnp.zeros(()), jnp.asarray(skew),
                          jnp.zeros(())))
    ctl.maybe_replan(20)
    assert ctl.flat_skips == 1  # layer 1's skew reached the planner
