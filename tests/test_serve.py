"""Decode/KV-cache correctness: incremental decode must reproduce the full
forward pass, for every architecture family — plus the ISSUE-5 decode
regression: the jitted serve step under a shadowed (per-layer) placement is
bit-exact vs the unshadowed decode on a fake-device mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dist_utils as du
from repro.configs import get_config, reduced
from repro.launch.serve import cache_len_for, generate
from repro.models import lm

# one representative per family (plus MLA + sliding window specials)
DECODE_ARCHS = ["smollm-360m", "deepseek-v2-236b", "rwkv6-7b", "hymba-1.5b",
                "starcoder2-15b"]
S = 12


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_incremental_decode_matches_forward(name):
    cfg = reduced(get_config(name))
    if cfg.moe is not None:
        # forward pools all tokens -> capacity overflow can drop some; decode
        # never drops (tiny per-step batches).  Equivalence holds at no-drop
        # capacity, which is what we verify here.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, tokens)

    cache = lm.init_cache(cfg, 2, cache_len=32)
    outs = []
    for t in range(S):
        logits, cache, _ = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                          jnp.int32(t), cache)
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_ring_buffer_wraparound_matches_windowed_forward():
    """Cache shorter than the sequence: ring overwrite must equal a
    sliding-window forward pass."""
    cfg = reduced(get_config("smollm-360m"))
    W = 8
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, sliding_window=W))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, tokens)  # SWA forward

    cache = lm.init_cache(cfg, 1, cache_len=W)  # ring == window
    outs = []
    for t in range(20):
        logits, cache, _ = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                          jnp.int32(t), cache)
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_whisper_decode_with_cross_attention():
    cfg = reduced(get_config("whisper-tiny"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (2, cfg.encoder.num_frames, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, tokens, frames=frames)

    enc = lm.encode(params, cfg, frames)
    cache = lm.init_cache(cfg, 2, cache_len=32, enc_out=enc)
    outs = []
    for t in range(S):
        logits, cache, _ = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                          jnp.int32(t), cache)
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_generate_greedy_deterministic():
    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0, cfg.vocab_size)
    s1 = generate(params, cfg, prompt, steps=6, cache_len=32)
    s2 = generate(params, cfg, prompt, steps=6, cache_len=32)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert s1.shape == (2, 10)


def test_serve_step_shadowed_decode_bit_exact():
    """ISSUE-5 decode regression: jit_serve_step with a per-layer plan whose
    hot experts are shadowed (psum mode skips them in the reduction, serves
    them locally) produces bit-identical logits to the unshadowed decode,
    step after step, on a 1x4 fake-device mesh."""
    out = du.run("""
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import jit_serve_step
    from repro.launch.train import moe_dist
    from repro.models import lm
    from repro.placement import from_logical, per_layer_placement

    cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, capacity_factor=8.0))
    mesh = make_local_mesh(1, 4)
    B, SEQ = 2, 16
    # decode tokens (B*1 = 2) don't split over 4 devices -> psum mode
    probe = moe_dist(cfg, mesh, B, opts={})
    assert probe is not None and probe.mode == "psum", probe
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0,
                              cfg.vocab_size)

    # measure per-layer loads once, then shadow each layer's 4 hottest
    _, _, loads = lm.forward(params, cfg, toks, layer_loads=True)
    plp = per_layer_placement([
        du.hot_shadow_plan(np.asarray(loads[l]), 4, 4)
        for l in range(cfg.num_layers)])
    assert plp.num_shadow == 4
    # the unshadowed control: the SAME per-layer layout with shadowing off
    # (identical migrated params — the only variable is the shadow set)
    plp0 = per_layer_placement([p._replace(num_shadow=0)
                                for p in plp.layers])

    def decode(opts, p):
        step, _ = jit_serve_step(cfg, mesh, B, SEQ, opts=opts)
        cache = lm.init_cache(cfg, B, SEQ)
        outs = []
        with mesh:
            for t in range(6):
                logits, cache, _ = step(p, toks[:, t:t+1], jnp.int32(t), cache)
                outs.append(np.asarray(logits))
        return outs

    plain = decode({}, params)
    pp = from_logical(params, plp)
    base = decode({"placement": plp0}, pp)
    shadowed = decode({"placement": plp}, pp)
    for t, (a, b) in enumerate(zip(base, shadowed)):
        du.assert_bit_exact(a, b, msg=t)
    for t, (a, b) in enumerate(zip(plain, base)):  # placed vs plain: ~ulp
        assert np.abs(a - b).max() < 2e-3, t
    print("serve shadow decode bit-exact ok")
    """, devices=4)
    assert "serve shadow decode bit-exact ok" in out


def test_cache_len_for_policy():
    sc = get_config("starcoder2-15b")  # SWA 4096
    assert cache_len_for(sc, 524288) == 4096
    qw = get_config("qwen2-72b")  # full attention -> SWA_CAP at 500k
    assert cache_len_for(qw, 524288) == 8192
    assert cache_len_for(qw, 32768) == 32768
    rw = get_config("rwkv6-7b")
    assert cache_len_for(rw, 524288) == 1
