"""Hierarchical two-level ragged exchange (ISSUE 7 tentpole tests).

The two-level path (intra-node aggregation hop + slim inter-node hop,
``DistConfig.node_axis``) must be *the same function* as the flat ragged
exchange: outputs AND grads bit-identical on the 2-node x 4-inner fake
mesh across dispatch impls, overlap chunking, the bf16 wire, and slim
inter bounds — while the wire counters split intra/inter and the
inter-node share shrinks below the flat exchange's bytes.

Host tests exercise the pure plan math (core/dispatch make_hier_agg /
ragged_recv_compact_hier / hier_chunk_plans), the compat shim, and the
LoadMonitor's adaptive bound; multi-device cases run in subprocesses via
tests/dist_utils.py (the main process keeps its single CPU device).
"""
import jax
import jax.numpy as jnp
import numpy as np

import dist_utils as du
from repro import compat
from repro.core import dispatch as D
from repro.core.monitor import LoadMonitor


# ---------------------------------------------------------------------------
# Host-level: the aggregation / compaction / chunk plan index math
# ---------------------------------------------------------------------------


def _agg_env(seed=0, n_nodes=2, n_inner=2, e_local=2, bound=4):
    rng = np.random.default_rng(seed)
    cnt = rng.integers(0, bound // e_local + 1, (n_nodes, n_inner, e_local))
    while cnt.sum(-1).max() > bound:  # per-(node, sibling) shard must fit
        cnt = rng.integers(0, bound, (n_nodes, n_inner, e_local))
    return jnp.asarray(cnt, jnp.int32)


def test_hier_agg_compacts_sibling_prefixes():
    """make_hier_agg: the forwarding agent packs its siblings' valid
    prefixes back to back per destination node — no inter-source padding
    crosses the node boundary."""
    cnt = _agg_env()
    n_nodes, n_inner, e_local = cnt.shape
    bound, ib = 4, int(cnt.sum(axis=(1, 2)).max())  # dropless inter bound
    plan = D.make_hier_agg(cnt, bound, ib)
    dest = np.asarray(plan.agg_dest).reshape(n_nodes, n_inner, bound)
    seg = np.asarray(cnt.sum(-1))
    for o in range(n_nodes):
        expect, pos = [], 0
        for s in range(n_inner):
            expect += list(range(o * ib + pos, o * ib + pos + seg[o, s]))
            pos += seg[o, s]
            # padding rows past the valid prefix are routed to the drop slot
            assert (dest[o, s, seg[o, s]:] == n_nodes * ib).all()
        got = [d for d in dest[o].ravel() if d < n_nodes * ib]
        assert got == expect, (o, got, expect)
    np.testing.assert_array_equal(np.asarray(plan.kept_counts), np.asarray(cnt))
    assert float(plan.dropped) == 0.0


def test_hier_agg_bound_drops_trailing_and_counts():
    """A sub-dropless inter bound truncates each node's trailing rows; the
    kept counts shrink expert-granular and the dropped total matches."""
    cnt = jnp.asarray([[[2, 1], [3, 0]],          # node 0: 6 rows
                       [[0, 2], [1, 1]]], jnp.int32)  # node 1: 4 rows
    plan = D.make_hier_agg(cnt, 4, 5)
    dest = np.asarray(plan.agg_dest).reshape(2, 2, 4)
    # node 0: sibling 0 keeps 3, sibling 1's 3 rows hit positions 3,4,(5=cut)
    assert [d for d in dest[0].ravel() if d < 10] == [0, 1, 2, 3, 4]
    kept = np.asarray(plan.kept_counts)
    np.testing.assert_array_equal(kept[0], [[2, 1], [2, 0]])  # last row cut
    np.testing.assert_array_equal(kept[1], np.asarray(cnt)[1])  # fits
    assert float(plan.dropped) == 1.0


def test_hier_recv_compact_matches_flat_order():
    """The receiver of the slim inter leg rebuilds the *exact* flat-path
    compact array: expert-major segments, source-rank-major inside (ranks
    node-major) — emulated in numpy against ragged_recv_compact."""
    rng = np.random.default_rng(1)
    n_nodes, n_inner, e_local, bound = 2, 3, 2, 5
    ib = n_inner * bound
    cnt = rng.integers(0, 3, (n_nodes, n_inner, e_local)).astype(np.int32)
    incoming = jnp.asarray(cnt)
    # slim buffers as the agents pack them: per node, sibling-major prefixes
    rows = []
    for o in range(n_nodes):
        node_rows = [(o * n_inner + s, e, r)
                     for s in range(n_inner) for e in range(e_local)
                     for r in range(cnt[o, s, e])]
        rows += node_rows + [(-1, -1, -1)] * (ib - len(node_rows))
    rows = np.asarray(rows)  # (n_nodes * ib, 3) tagged source rows
    cplan, gs = D.ragged_recv_compact_hier(incoming, ib)
    cplan = np.asarray(cplan)
    n_valid = int(cnt.sum())
    compact = np.full((n_nodes * ib + 1, 3), -1)
    compact[cplan] = rows
    compact = compact[:n_valid]
    # flat-path oracle: same rows through ragged_recv_compact on the
    # equivalent (mp, bound) shards
    flat_cnt = jnp.asarray(cnt.reshape(n_nodes * n_inner, e_local))
    fplan, fgs = D.ragged_recv_compact(flat_cnt, bound)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(fgs))
    frows = np.asarray([(p, e, r) for p in range(n_nodes * n_inner)
                        for e in range(e_local)
                        for r in range(cnt.reshape(-1, e_local)[p, e])]
                       + [(-1, -1, -1)] * 0)
    fcompact = np.full((n_nodes * n_inner * bound + 1, 3), -1)
    # flat send buffers: per peer, expert-major valid prefix then padding
    fsend = []
    for p in range(n_nodes * n_inner):
        peer = [(p, e, r) for e in range(e_local)
                for r in range(cnt.reshape(-1, e_local)[p, e])]
        fsend += peer + [(-1, -1, -1)] * (bound - len(peer))
    fcompact[np.asarray(fplan)] = np.asarray(fsend)
    np.testing.assert_array_equal(compact, fcompact[:n_valid])


def test_hier_chunk_plans_partition_the_full_plan():
    """Per-chunk mini-compactions cover every valid row exactly once and
    their group sizes sum to the full receive's group sizes."""
    rng = np.random.default_rng(2)
    n_nodes, n_inner, e_local = 2, 2, 2
    ib, n_chunks = 8, 4
    cnt = rng.integers(0, 3, (n_nodes, n_inner, e_local)).astype(np.int32)
    incoming = jnp.asarray(cnt)
    cdest, cgs = D.hier_chunk_plans(incoming, ib, n_chunks)
    _, gs = D.ragged_recv_compact_hier(incoming, ib)
    cdest, cgs = np.asarray(cdest), np.asarray(cgs)
    w = ib // n_chunks
    assert cdest.shape == (n_chunks, n_nodes * w)
    np.testing.assert_array_equal(cgs.sum(0), np.asarray(gs))
    for c in range(n_chunks):
        # each chunk's valid rows (invalid slots -> the n_nodes*w drop slot)
        # fill their own mini compact array exactly once
        valid = cdest[c][cdest[c] < n_nodes * w]
        assert len(valid) == cgs[c].sum()
        np.testing.assert_array_equal(np.sort(valid),
                                      np.arange(len(valid)))


def test_suggest_ragged_bound_adapts_and_guards():
    mon = LoadMonitor(8, ema=0.5)
    # un-warmed monitor: never-drop bound
    assert mon.suggest_ragged_bound(64, 2, 4) == 64 * 2
    # warm with a uniform load: peak peer share = 1/4
    load = np.ones(8)
    for _ in range(64):
        mon.update(type("M", (), {"load": load, "drop_frac": 0.0})())
    b = mon.suggest_ragged_bound(64, 2, 4)
    assert b == 40  # ceil(128 * 0.25 * 1.25) = 40, already a multiple of 8
    assert b % 8 == 0 and b < 128
    # skew every row onto peer 0: bound walks back toward dropless
    mon2 = LoadMonitor(8, ema=0.5)
    hot = np.asarray([8.0, 8, 0, 0, 0, 0, 0, 0])
    for _ in range(64):
        mon2.update(type("M", (), {"load": hot, "drop_frac": 0.0})())
    assert mon2.suggest_ragged_bound(64, 2, 4) == 128  # peak ~ 1.0, clamp n
    # drop guard: EMA evidence of clipping forces the never-drop bound
    mon.update(type("M", (), {"load": load, "drop_frac": 1.0})())
    assert mon.suggest_ragged_bound(64, 2, 4) == 128


def test_compat_shim_version_gate():
    """has_ragged_all_to_all reflects the installed jax: true iff
    lax.ragged_all_to_all exists.  (The fallback-vs-native equality runs in
    the subprocess test below; on jax without the primitive both calls take
    the fallback, which the flat-exchange differential already pins.)"""
    has = compat.has_ragged_all_to_all()
    assert has == hasattr(jax.lax, "ragged_all_to_all")


# ---------------------------------------------------------------------------
# Multi-device: flat vs two-level differential + counters + composition
# ---------------------------------------------------------------------------

_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    env = du.moe_env(dispatch="ragged", capacity_factor=1.25)
    mesh = du.make_mesh(1, 4, node=2)  # (data, node, model) = (1, 2, 4)
    AX = ("data", "node", "model")
    EXP = ("node", "model")
    flat = fmoe.DistConfig(mesh, AX, expert_axis=EXP)
    hier = flat._replace(node_axis="node")
"""


def test_hier_bit_exact_vs_flat_sweep():
    """Acceptance: the two-level exchange is bit-exact vs the flat ragged
    path — outputs AND grads — across impl x overlap x inter_bound on the
    2-node x 4-inner mesh (8 fake devices).  ib=24 < n_inner*B exercises
    the slim (but still dropless for this routing) inter leg; oc=4 with
    pallas/fused exercises per-received-chunk expert compute."""
    out = du.run(_SETUP + """
    def loss(p, x, dist, impl):
        y, _ = fmoe.fmoe_apply(p, x, env.cfg, dist=dist, impl=impl)
        return (y ** 2).mean()

    def run(dist, impl):
        with mesh:
            fn = jax.jit(lambda p, x: (
                fmoe.fmoe_apply(p, x, env.cfg, dist=dist, impl=impl)[0],
                jax.grad(loss)(p, x, dist, impl)))
            y, g = fn(env.params, env.x)
        return np.asarray(y), g

    corners = [(impl, oc, ib) for impl in ("einsum", "fused") for oc in (0, 4)
               for ib in (0, 24)] + [("pallas", 4, 24), ("pallas", 0, 0)]
    for impl, oc, ib in corners:
        y0, g0 = run(flat._replace(overlap_chunks=oc), impl)
        y1, g1 = run(hier._replace(overlap_chunks=oc, inter_bound=ib), impl)
        du.assert_bit_exact(y1, y0, msg=(impl, oc, ib))
        du.assert_grads_match(g1, g0)
    # bf16 wire: both levels cast; still bit-exact flat vs hier (identical
    # quantization points), and distinct from the f32-wire output
    yb0, _ = run(flat._replace(wire_dtype="bf16"), "fused")
    yb1, _ = run(hier._replace(wire_dtype="bf16", inter_bound=24), "fused")
    du.assert_bit_exact(yb1, yb0)
    y0, _ = run(flat, "fused")
    assert 0 < float(np.abs(yb0 - y0).max()) < 0.05
    print("hier bit-exact ok")
    """, devices=8)
    assert "hier bit-exact ok" in out


def test_hier_wire_counters_hand_math_hlo_and_shrink():
    """The split counters' contract: wire_bytes == intra + inter, both match
    the hand math AND the optimized HLO's collective bytes, flat counts
    everything as inter, and a slim inter_bound shrinks ONLY the inter-node
    share — below the flat exchange's bytes."""
    out = du.run(_SETUP + """
    from repro.launch.roofline import collective_bytes
    def run(dist):
        with mesh:
            fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, env.cfg,
                                                      dist=dist))
            y, m = fn(env.params, env.x)
            txt = fn.lower(env.params, env.x).compile().as_text()
        cb = collective_bytes(txt)
        return m, float(cb.get("all-to-all", 0)
                        + cb.get("collective-permute", 0))

    E, d, mp, n_inner, n_nodes = 8, 32, 8, 4, 2
    B = (128 // 8) * 2  # t_local * k = 32 rows per peer shard
    # flat on the node mesh: everything crosses as inter
    m, hlo = run(flat)
    b_flat = 4 * (2 * mp * B * d + E)
    assert float(m.obs.wire_bytes) == b_flat == hlo, (
        float(m.obs.wire_bytes), b_flat, hlo)
    assert float(m.obs.wire_bytes_intra) == 0.0
    assert float(m.obs.wire_bytes_inter) == b_flat

    # hier dropless (IB = n_inner * B): every row crosses both levels
    m, hlo = run(hier)
    b_intra = 4 * (2 * mp * B * d + E)
    b_inter = 4 * (2 * n_nodes * n_inner * B * d + E)
    assert float(m.obs.wire_bytes_intra) == b_intra
    assert float(m.obs.wire_bytes_inter) == b_inter
    assert float(m.obs.wire_bytes) == b_intra + b_inter == hlo, (
        float(m.obs.wire_bytes), b_intra + b_inter, hlo)

    # slim inter bound: the inter share (the slow links) shrinks below the
    # flat exchange's bytes; the intra share is untouched
    m24, hlo24 = run(hier._replace(inter_bound=24))
    b_inter24 = 4 * (2 * n_nodes * 24 * d + E)
    assert float(m24.obs.wire_bytes_intra) == b_intra
    assert float(m24.obs.wire_bytes_inter) == b_inter24
    assert b_inter24 < b_flat
    assert float(m24.obs.wire_bytes) == b_intra + b_inter24 == hlo24
    assert float(m24.drop_frac) == 0.0  # this routing still fits

    # decomposed (ppermute) hops: each level keeps its own (s-1)/s fraction
    md, hlod = run(hier._replace(overlap_chunks=4, inter_bound=24))
    bi = 0.75 * b_intra
    be = 0.5 * b_inter24
    assert float(md.obs.wire_bytes_intra) == bi
    assert float(md.obs.wire_bytes_inter) == be
    assert float(md.obs.wire_bytes) == bi + be == hlod

    # bf16 wire: payloads halve on both levels, counts legs stay int32
    mb, hlob = run(hier._replace(wire_dtype="bf16", inter_bound=24))
    assert float(mb.obs.wire_bytes_intra) == 2 * (2 * mp * B * d) + 4 * E
    assert float(mb.obs.wire_bytes_inter) == 2 * (2 * n_nodes * 24 * d) + 4 * E
    assert float(mb.obs.wire_bytes) == hlob
    print("hier counters ok")
    """, devices=8)
    assert "hier counters ok" in out


def test_hier_skew_drops_and_shadow_compose():
    """Zipf-skewed routing under a too-slim inter bound: the forwarding
    agents' truncations land in drop_frac, outputs stay finite; shadowed
    hot experts compose with the two-level exchange (the shadow tail never
    enters either hop)."""
    out = du.run(_SETUP + """
    from repro.placement import from_logical
    skew = du.skew_router(env)  # all rows to experts {0, 1} = node 0
    y_ref, m_ref = du.oracle(skew, impl="fused")
    y, m = du.dist_apply(skew, mesh, hier, impl="fused")
    du.assert_close(y, y_ref, 1e-5)
    assert float(m.drop_frac) == 0.0  # dropless bounds
    load = np.asarray(m.load)
    np.testing.assert_allclose(load[:2], [0.5, 0.5], atol=1e-6)

    # slim the inter leg below the hot node's arrivals: every rank splits
    # its 32 rows between experts 0/1 (node 0's inner slots 0/1), so each
    # of the 4 forwarding agents involved aggregates 4 siblings x 16 = 64
    # rows for node 0 and IB=32 keeps half -> global drop_frac = 0.5
    yb, mb = du.dist_apply(skew, mesh, hier._replace(inter_bound=32),
                           impl="fused")
    np.testing.assert_allclose(float(mb.drop_frac), 0.5, atol=1e-6)
    assert np.isfinite(np.asarray(yb)).all()

    # shadow placement: hot experts replicated outside both hops (16
    # experts: shadowing 8 leaves 8 owned = 1 per rank)
    env16 = du.moe_env(dispatch="ragged", num_experts=16,
                       capacity_factor=1.25)
    y0, m0 = du.dist_apply(env16, mesh, hier)
    plan = du.hot_shadow_plan(np.asarray(m0.load), 8, 8)
    pp = from_logical(env16.params, plan)
    for oc in (0, 4):
        y1, m1 = du.dist_apply(env16, mesh, hier._replace(
            placement=plan, overlap_chunks=oc), params=pp)
        du.assert_close(y1, y0, 1e-5, msg=oc)
        np.testing.assert_allclose(np.asarray(m1.load), np.asarray(m0.load),
                                   atol=1e-6)
    print("hier skew+shadow ok")
    """, devices=8)
    assert "hier skew+shadow ok" in out


def test_compat_shim_branches_agree():
    """compat.ragged_all_to_all_shards: the dense bounded-shard fallback is
    bit-identical to the native ragged primitive (when the installed jax
    has it) and to the plain tiled a2a (always — zero padding is the
    invariant both transports preserve)."""
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    import dist_utils as du
    mesh = du.make_mesh(1, 4)
    mp, bound, d = 4, 6, 8
    rng = np.random.default_rng(0)
    sizes = np.asarray([[3, 1, 0, 6], [2, 2, 2, 2],
                        [0, 0, 1, 5], [6, 6, 6, 6]], np.int32)
    send = np.zeros((mp, mp, bound, d), np.float32)  # [rank, dest, row, d]
    for r in range(mp):
        for p in range(mp):
            send[r, p, :sizes[r, p]] = rng.normal(size=(sizes[r, p], d))

    def make_run(force):
        def run(s, sz):
            recv_sz = jax.lax.all_to_all(sz[0].reshape(mp, 1), "model", 0, 0,
                                         tiled=True).reshape(mp)
            return compat.ragged_all_to_all_shards(
                s[0], sz[0], recv_sz, "model", force_fallback=force)[None]
        return compat.shard_map(run, mesh=mesh,
                                in_specs=(P("model"), P("model")),
                                out_specs=P("model"))

    outs = {}
    for force in ((False, True) if compat.has_ragged_all_to_all()
                  else (True,)):
        with mesh:
            outs[force] = np.asarray(make_run(force)(jnp.asarray(send),
                                                     jnp.asarray(sizes)))
    # oracle: the plain tiled a2a of the padded shards
    plain = compat.shard_map(
        lambda s: jax.lax.all_to_all(s[0], "model", 0, 0, tiled=True)[None],
        mesh=mesh, in_specs=(P("model"),), out_specs=P("model"))
    with mesh:
        ref = np.asarray(plain(jnp.asarray(send)))
    for force, got in outs.items():
        du.assert_bit_exact(got, ref, msg=force)
    print("shim branches ok")
    """, devices=4)
    assert "shim branches ok" in out


def test_train_cli_runs_hier_mesh_with_auto_bounds():
    """launch/train.py accepts the 3-dim --mesh DATAxNODExMODEL plus
    --ragged_bound auto (LoadMonitor-calibrated bounds re-resolved at every
    placement replan) and takes optimizer steps."""
    out = du.run_cli(
        ["repro.launch.train", "--arch", "fastmoe-gpt", "--reduced",
         "--steps", "3", "--batch", "4", "--seq", "32", "--mesh", "1x2x4",
         "--dispatch", "ragged", "--impl", "fused", "--overlap_chunks", "2",
         "--ragged_bound", "auto", "--log_every", "1"], devices=8)
    assert "done: 3 steps" in out, out
