"""Prefill fast path: one full pass fills the decode cache; continuation
must match token-by-token decoding exactly, for every cache family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.serve import generate
from repro.models import lm

ARCHS = ["smollm-360m", "deepseek-v2-236b", "rwkv6-7b", "hymba-1.5b",
         "whisper-tiny"]
B, S = 2, 10


def _setup(name):
    cfg = reduced(get_config(name))
    if cfg.moe is not None:  # no-drop capacity so prefill==decode exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kw, enc = {}, None
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.num_frames, cfg.d_model))
        kw["frames"] = frames
        enc = lm.encode(params, cfg, frames)
    return cfg, params, toks, kw, enc


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_cache_matches_decode_cache(name):
    cfg, params, toks, kw, enc = _setup(name)
    cache_p = lm.init_cache(cfg, B, 32, enc_out=enc)
    logits_p, cache_p, _ = lm.prefill(params, cfg, toks, cache_p, **kw)

    cache_d = lm.init_cache(cfg, B, 32, enc_out=enc)
    for t in range(S):
        logits_d, cache_d, _ = lm.decode_step(params, cfg, toks[:, t:t + 1],
                                              jnp.int32(t), cache_d)
    # last-position logits agree
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(logits_d[:, 0]), atol=2e-4)
    # continuation from either cache agrees
    nxt = jnp.ones((B, 1), jnp.int32)
    lp, _, _ = lm.decode_step(params, cfg, nxt, jnp.int32(S), cache_p)
    ld, _, _ = lm.decode_step(params, cfg, nxt, jnp.int32(S), cache_d)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld), atol=2e-4)


def test_prefill_ring_overflow_keeps_tail():
    """Prompt longer than the ring: prefill keeps the last W entries."""
    cfg = reduced(get_config("smollm-360m"))
    cfg = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, sliding_window=8))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, cfg.vocab_size)
    W = 8
    cache_p = lm.init_cache(cfg, 1, W)
    _, cache_p, _ = lm.prefill(params, cfg, toks, cache_p)
    cache_d = lm.init_cache(cfg, 1, W)
    for t in range(20):
        _, cache_d, _ = lm.decode_step(params, cfg, toks[:, t:t + 1],
                                       jnp.int32(t), cache_d)
    np.testing.assert_array_equal(np.asarray(cache_p["positions"]
                                             if isinstance(cache_p, dict)
                                             else cache_p.positions),
                                  np.asarray(cache_d.positions))
    nxt = jnp.ones((1, 1), jnp.int32)
    lp, _, _ = lm.decode_step(params, cfg, nxt, jnp.int32(20), cache_p)
    ld, _, _ = lm.decode_step(params, cfg, nxt, jnp.int32(20), cache_d)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld), atol=2e-4)


def test_generate_prefill_equals_stepwise():
    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                                cfg.vocab_size)
    fast = generate(params, cfg, prompt, steps=5, cache_len=32,
                    use_prefill=True)
    slow = generate(params, cfg, prompt, steps=5, cache_len=32,
                    use_prefill=False)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))
