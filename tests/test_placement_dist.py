"""Distributed placement/shadowing tests — subprocesses with fake devices
(same contract as tests/test_distributed.py: the main process keeps its
single CPU device)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.core import fmoe, naive
    from repro.placement import ExpertPlacement, from_logical
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=64,
                    capacity_factor=8.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
    dist0 = fmoe.DistConfig(mesh, ("data", "model"))
    with mesh:
        y0, m0 = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg, dist=dist0))(params, x)
    load = np.asarray(m0.load)
    hot = np.argsort(-load)
    def plan_for(S):
        phys = tuple(int(e) for e in np.sort(hot[S:])) + tuple(int(e) for e in hot[:S])
        return ExpertPlacement(8, 4, phys, num_shadow=S, capacity_scale=1.0)
"""


def test_shadowed_a2a_matches_unshadowed():
    """Acceptance: shadowing is numerically equivalent to the baseline a2a,
    for both a pure permutation (S=0) and replicated hot experts (S=4)."""
    out = _run(_SETUP + """
    y_ref = naive.moe_loop_masked(params, x, cfg)
    assert float(jnp.abs(y0 - y_ref).max()) < 1e-5
    for S in (0, 4):
        pl = plan_for(S)
        pp = from_logical(params, pl)
        dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
        with mesh:
            y1, m1 = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg, dist=dist))(pp, x)
        err = float(jnp.abs(y1 - y0).max())
        assert err < 1e-5, (S, err)
        assert np.allclose(np.asarray(m1.load), load), S  # logical order
    print("shadow equivalence ok")
    """)
    assert "shadow equivalence ok" in out


def test_shadowed_a2a_shrinks_exchange_bytes():
    """Acceptance: replication degree > 1 reduces the exchanged buffer."""
    out = _run(_SETUP + """
    from repro.launch import roofline
    def a2a_bytes(dist, p):
        with mesh:
            txt = jax.jit(lambda pa, xx: fmoe.fmoe_apply(pa, xx, cfg, dist=dist)[0]
                          ).lower(p, x).compile().as_text()
        return roofline.collective_bytes(txt).get("all-to-all", 0)
    b0 = a2a_bytes(dist0, params)
    pl = plan_for(4)
    assert int(pl.replication.max()) == 4  # degree > 1 on the shadowed set
    b1 = a2a_bytes(fmoe.DistConfig(mesh, ("data", "model"), placement=pl),
                   from_logical(params, pl))
    assert 0 < b1 < b0, (b0, b1)
    print("a2a bytes", b0, "->", b1)
    """)
    assert "a2a bytes" in out


def test_shadowed_gradients_flow_and_sync():
    """Replicated shadow-expert grads must be identical across ranks (the
    all-reduce the cost model charges for); owned-expert grads stay sharded."""
    print(_run(_SETUP + """
    pl = plan_for(4)
    pp = from_logical(params, pl)
    dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
    def loss(p):
        y, m = fmoe.fmoe_apply(p, x, cfg, dist=dist)
        return (y ** 2).mean() + 0.01 * m.aux_loss
    with mesh:
        g = jax.jit(jax.grad(loss))(pp)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(g))
    # grads exist for every expert (shadowed included)
    gw = np.asarray(g["experts"]["wi_gate"], np.float32)
    assert (np.abs(gw).sum(axis=(1, 2)) > 0).all()
    print("shadow grads ok")
    """))


def test_capacity_shrink_equivalent_when_no_drops():
    """capacity_scale < 1 must stay numerically equivalent while capacity
    still covers the actual load (cf is generous here)."""
    print(_run(_SETUP + """
    pl0 = plan_for(4)
    pl = ExpertPlacement(8, 4, pl0.physical_to_logical, num_shadow=4,
                         capacity_scale=0.5)
    pp = from_logical(params, pl)
    dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
    with mesh:
        y1, m1 = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg, dist=dist))(pp, x)
    err = float(jnp.abs(y1 - y0).max())
    assert err < 1e-5, err
    assert float(m1.drop_frac) == float(m0.drop_frac)
    print("capacity shrink ok", err)
    """))


def test_replan_hook_migrates_live_training():
    """End-to-end: train on a mesh, force a replan, keep training — loss
    stays finite and the migrated layout keeps learning."""
    print(_run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.configs.base import MoEConfig
    import dataclasses
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import ReplanHook, jit_train_step
    from repro.models import lm
    from repro.optim import AdamW
    cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_experts=16))
    mesh = make_local_mesh(1, 4)
    opt = AdamW()
    B, S = 8, 32
    step_fn, pshard, oshard = jit_train_step(cfg, opt, mesh, B, S)
    params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg), pshard)
    opt_state = jax.device_put(opt.init(params), oshard)
    hook = ReplanHook(cfg, opt, mesh, B, S, every=2)
    hook.controller.min_gain = -10.0  # force accept to exercise migration
    skew = 1.0 / (np.arange(16) + 1) ** 1.5
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    replans, losses = 0, []
    for step in range(6):
        with mesh:
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jnp.int32(step))
        losses.append(float(m["loss"]))
        params, opt_state, new_fn = hook.observe(
            step, {"load": skew, "drop_frac": 0.0}, params, opt_state)
        if new_fn is not None:
            step_fn = new_fn
            replans += 1
    assert replans >= 1, "replan never fired"
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] + 0.5, losses  # still learning post-migration
    print("replan hook ok", replans, [round(l, 3) for l in losses])
    """, devices=4))
