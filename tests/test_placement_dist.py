"""Distributed placement/shadowing tests — subprocesses with fake devices
(tests/dist_utils.py is the consolidated harness; the main process keeps its
single CPU device).

ISSUE-5 acceptance lives here: per-layer plans are bit-exact vs the
shared-plan path when every layer sees the same load, a skewed (L, E) load
yields genuinely distinct per-layer physical layouts, and the decode (psum)
path with shadowed hot experts is bit-exact vs the unshadowed decode.
"""
import dist_utils as du

_SETUP = """
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    from repro.placement import from_logical
    env = du.moe_env()
    mesh = du.make_mesh()
    dist0 = fmoe.DistConfig(mesh, ("data", "model"))
    y0, m0 = du.dist_apply(env, mesh, dist0)
    load = np.asarray(m0.load)
"""


def test_shadowed_a2a_matches_unshadowed():
    """Acceptance (PR 1): shadowing is numerically equivalent to the baseline
    a2a, for both a pure permutation (S=0) and replicated hot experts."""
    out = du.run(_SETUP + """
    from repro.core import naive
    y_ref = naive.moe_loop_masked(env.params, env.x, env.cfg)
    du.assert_close(y0, y_ref, 1e-5)
    for S in (0, 4):
        pl = du.hot_shadow_plan(load, 4, S)
        pp = from_logical(env.params, pl)
        dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
        y1, m1 = du.dist_apply(env, mesh, dist, params=pp)
        du.assert_close(y1, y0, 1e-5, msg=S)
        assert np.allclose(np.asarray(m1.load), load), S  # logical order
    print("shadow equivalence ok")
    """)
    assert "shadow equivalence ok" in out


def test_shadowed_a2a_shrinks_exchange_bytes():
    """Acceptance (PR 1): replication degree > 1 reduces the exchanged buffer."""
    out = du.run(_SETUP + """
    from repro.launch import roofline
    def a2a_bytes(dist, p):
        with mesh:
            txt = jax.jit(lambda pa, xx: fmoe.fmoe_apply(
                pa, xx, env.cfg, dist=dist)[0]).lower(p, env.x).compile().as_text()
        return roofline.collective_bytes(txt).get("all-to-all", 0)
    b0 = a2a_bytes(dist0, env.params)
    pl = du.hot_shadow_plan(load, 4, 4)
    assert int(pl.replication.max()) == 4  # degree > 1 on the shadowed set
    b1 = a2a_bytes(fmoe.DistConfig(mesh, ("data", "model"), placement=pl),
                   from_logical(env.params, pl))
    assert 0 < b1 < b0, (b0, b1)
    print("a2a bytes", b0, "->", b1)
    """)
    assert "a2a bytes" in out


def test_shadowed_gradients_flow_and_sync():
    """Replicated shadow-expert grads must be identical across ranks (the
    all-reduce the cost model charges for); owned-expert grads stay sharded."""
    print(du.run(_SETUP + """
    pl = du.hot_shadow_plan(load, 4, 4)
    pp = from_logical(env.params, pl)
    dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
    g = du.layer_grads(env, dist, mesh=mesh, params=pp)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(g))
    # grads exist for every expert (shadowed included)
    gw = np.asarray(g["experts"]["wi_gate"], np.float32)
    assert (np.abs(gw).sum(axis=(1, 2)) > 0).all()
    print("shadow grads ok")
    """))


def test_capacity_shrink_equivalent_when_no_drops():
    """capacity_scale < 1 must stay numerically equivalent while capacity
    still covers the actual load (cf is generous here)."""
    print(du.run(_SETUP + """
    pl = du.hot_shadow_plan(load, 4, 4, capacity_scale=0.5)
    pp = from_logical(env.params, pl)
    dist = fmoe.DistConfig(mesh, ("data", "model"), placement=pl)
    y1, m1 = du.dist_apply(env, mesh, dist, params=pp)
    du.assert_close(y1, y0, 1e-5)
    assert float(m1.drop_frac) == float(m0.drop_frac)
    print("capacity shrink ok")
    """))


# ---------------------------------------------------------------------------
# Per-layer plans (ISSUE 5 tentpole acceptance)
# ---------------------------------------------------------------------------


_LM_SETUP = """
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.configs import get_config, reduced
    from repro.core.fmoe import DistConfig
    from repro.models import lm
    from repro.placement import (from_logical, plan_placement,
                                 plan_placement_per_layer)
    cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, capacity_factor=8.0))
    E, L = 8, cfg.num_layers
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    dist0 = DistConfig(mesh, ("data", "model"))
    with mesh:
        logits0, m0, loads = jax.jit(lambda p, t: lm.forward(
            p, cfg, t, dist=dist0, layer_loads=True))(params, toks)
    kw = dict(d_model=cfg.d_model, d_hidden=cfg.moe.d_expert_hidden,
              capacity=4096)
    def run_placed(plan):
        pp = from_logical(params, plan)
        dist = DistConfig(mesh, ("data", "model"), placement=plan)
        with mesh:
            return jax.jit(lambda p, t: lm.forward(p, cfg, t,
                                                   dist=dist))(pp, toks)
"""


def test_per_layer_identical_load_bit_exact_vs_shared():
    """Acceptance: with every layer given the same load, the per-layer path
    degenerates to the shared plan — logits bitwise-identical."""
    out = du.run(_LM_SETUP + """
    row = np.asarray(loads[0])
    plp = plan_placement_per_layer(np.stack([row] * L), 4, **kw)
    shared = plan_placement(row, 4, **kw)
    assert all(p == shared for p in plp.layers)
    ys, _ = run_placed(shared)
    yp, _ = run_placed(plp)
    du.assert_bit_exact(ys, yp)
    print("per-layer degenerate bit-exact ok")
    """, devices=4)
    assert "per-layer degenerate bit-exact ok" in out


def test_per_layer_skewed_load_distinct_layouts():
    """Acceptance: a skewed (L, E) load produces >= 2 distinct per-layer
    physical layouts, and the placed forward still matches the baseline."""
    out = du.run(_LM_SETUP + """
    rng = np.random.default_rng(0)
    zipf = 1.0 / (np.arange(E) + 1) ** 1.5
    skew = np.stack([zipf[rng.permutation(E)] for _ in range(L)])
    plp = plan_placement_per_layer(skew, 4, **kw)
    layouts = {p.physical_to_logical for p in plp.layers}
    assert len(layouts) >= 2, layouts
    yp, mp_ = run_placed(plp)
    du.assert_close(yp, logits0, 2e-3)
    print("per-layer distinct layouts ok:", len(layouts),
          "shadow:", plp.num_shadow)
    """, devices=4)
    assert "per-layer distinct layouts ok" in out


def test_per_layer_grads_and_monitor_order():
    """Grads flow through the per-layer tables; the load monitor output
    stays in logical expert order for every layer."""
    print(du.run(_LM_SETUP + """
    rng = np.random.default_rng(1)
    zipf = 1.0 / (np.arange(E) + 1) ** 1.5
    plp = plan_placement_per_layer(
        np.stack([zipf[rng.permutation(E)] for _ in range(L)]), 4, **kw)
    pp = from_logical(params, plp)
    dist = DistConfig(mesh, ("data", "model"), placement=plp)
    def loss(p):
        return lm.loss_fn(p, cfg, {"tokens": toks}, dist=dist)[0]
    with mesh:
        g = jax.jit(jax.grad(loss))(pp)
        _, aux = jax.jit(lambda p: lm.loss_fn(p, cfg, {"tokens": toks},
                                              dist=dist))(pp)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(g))
    # per-layer loads in logical order == the unplaced baseline's
    np.testing.assert_allclose(np.asarray(aux["load_layers"]),
                               np.asarray(loads), atol=1e-6)
    print("per-layer grads + monitor order ok")
    """, devices=4))


# ---------------------------------------------------------------------------
# Decode (psum) shadowing — the serving half of the tentpole
# ---------------------------------------------------------------------------


def test_psum_decode_shadowing_bit_exact():
    """Acceptance: psum decode with shadowed hot experts == the unshadowed
    decode, bitwise, on both dispatch modes (1x4 fake-device mesh).

    The unshadowed control is the SAME physical layout with num_shadow=0
    (identical migrated params — the only variable is shadowing), and the
    S=0 permuted plan must in turn match the plain unplaced decode to
    combine-rounding tolerance (the plain path keeps the k-fold-cheaper
    combined psum; placed runs use the slot-wise reduction).

    Bitwise holds on every (dispatch, impl) cell except ragged+einsum: the
    slot-wise combine reduces across ranks before the fixed-order k-sum
    (dispatch.combine_capacity_slots), and the Pallas grouped kernels
    accumulate group-relative (pad_to_tiles), so nothing observes WHERE an
    expert's rows sit — but XLA's ragged_dot lowering is group-structure-
    sensitive (a 1-group call simplifies differently than a 2-group call),
    so that one cell gets an ulp-tolerance instead.
    """
    out = du.run("""
    import numpy as np, jax
    import dist_utils as du
    from repro.core import fmoe
    from repro.placement import from_logical
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    for dispatch, impl in [("capacity", "einsum"), ("capacity", "fused"),
                           ("ragged", "fused"), ("ragged", "pallas"),
                           ("ragged", "einsum")]:
        env = du.moe_env(dispatch=dispatch)
        dist0 = fmoe.DistConfig(mesh, ("data",))
        assert dist0.mode == "psum"
        y0, m0 = du.dist_apply(env, mesh, dist0, impl=impl)
        load = np.asarray(m0.load)
        pl4 = du.hot_shadow_plan(load, 4, 4)
        pl0 = pl4._replace(num_shadow=0)  # same layout, shadowing off
        # capacity_scale=0.5 must be a no-op here: psum has no a2a buffer
        # to shrink, so the plan's shrink must not introduce decode drops
        pl4s = pl4._replace(capacity_scale=0.5)
        pp = from_logical(env.params, pl4)  # same physical order for all
        def run(pl):
            dist = fmoe.DistConfig(mesh, ("data",), placement=pl)
            return du.dist_apply(env, mesh, dist, params=pp, impl=impl)
        y_un, m_un = run(pl0)
        du.assert_close(y_un, y0, 1e-5, msg=(dispatch, impl, "perm"))
        for tag, pl in (("S4", pl4), ("S4-shrunk", pl4s)):
            y1, m1 = run(pl)
            if (dispatch, impl) == ("ragged", "einsum"):
                du.assert_close(y1, y_un, 1e-5, msg=(dispatch, impl, tag))
            else:
                du.assert_bit_exact(y1, y_un, msg=(dispatch, impl, tag))
            assert np.allclose(np.asarray(m1.load), load), (dispatch, tag)
            assert float(m1.drop_frac) == float(m_un.drop_frac), tag
    print("psum shadow bit-exact ok")
    """, devices=4)
    assert "psum shadow bit-exact ok" in out


# ---------------------------------------------------------------------------
# Replan hook end to end (shared + per-layer)
# ---------------------------------------------------------------------------


_HOOK_SETUP = """
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import ReplanHook, jit_train_step
    from repro.models import lm
    from repro.optim import AdamW
    cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                           num_experts=16))
    mesh = make_local_mesh(1, 4)
    opt = AdamW()
    B, S = 8, 32
    step_fn, pshard, oshard = jit_train_step(cfg, opt, mesh, B, S)
    params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg), pshard)
    opt_state = jax.device_put(opt.init(params), oshard)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    def drive(hook, fake_metrics, steps=6):
        global step_fn, params, opt_state
        hook.controller.min_gain = -10.0  # force accept to exercise migration
        replans, losses = 0, []
        for step in range(steps):
            with mesh:
                params, opt_state, m = step_fn(params, opt_state, batch,
                                               jnp.int32(step))
            losses.append(float(m["loss"]))
            params, opt_state, new_fn = hook.observe(
                step, fake_metrics, params, opt_state)
            if new_fn is not None:
                step_fn = new_fn
                replans += 1
        return replans, losses
"""


def test_replan_hook_migrates_live_training():
    """End-to-end: train on a mesh, force a replan, keep training — loss
    stays finite and the migrated layout keeps learning."""
    print(du.run(_HOOK_SETUP + """
    hook = ReplanHook(cfg, opt, mesh, B, S, every=2)
    skew = 1.0 / (np.arange(16) + 1) ** 1.5
    replans, losses = drive(hook, {"load": skew, "drop_frac": 0.0})
    assert replans >= 1, "replan never fired"
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] + 0.5, losses  # still learning post-migration
    print("replan hook ok", replans, [round(l, 3) for l in losses])
    """, devices=4))


def test_replan_hook_per_layer_migrates_live_training():
    """Per-layer mode: the hook plans from (L, E) loads, migrates each
    layer's slice independently, and the re-jitted step keeps training."""
    print(du.run(_HOOK_SETUP + """
    from repro.placement import PerLayerPlacement
    hook = ReplanHook(cfg, opt, mesh, B, S, every=2, per_layer=True)
    rng = np.random.default_rng(0)
    zipf = 1.0 / (np.arange(16) + 1) ** 1.5
    skew = np.stack([zipf[rng.permutation(16)] for _ in range(cfg.num_layers)])
    replans, losses = drive(hook, {"load_layers": skew, "drop_frac": 0.0})
    assert replans >= 1, "per-layer replan never fired"
    assert isinstance(hook.placement, PerLayerPlacement)
    assert len({p.physical_to_logical for p in hook.placement.layers}) >= 2
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0] + 0.5, losses
    print("per-layer replan hook ok", replans, [round(l, 3) for l in losses])
    """, devices=4))
