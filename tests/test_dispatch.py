"""Scatter/gather dispatch tests (paper §4 Fig 4) — capacity + ragged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import dispatch as D


def _random_assignment(T, E, k, seed=0):
    rng = np.random.default_rng(seed)
    ids = np.stack([rng.permutation(E)[:k] for _ in range(T)])
    return jnp.asarray(ids, jnp.int32)


def test_capacity_roundtrip_no_drops():
    T, E, k, d = 32, 4, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (T, d))
    ids = _random_assignment(T, E, k)
    C = D.expert_capacity(T, E, k, 8.0)  # huge capacity: no drops
    plan = D.make_capacity_plan(ids, E, C)
    assert bool(plan.keep.all())
    buf = D.dispatch_capacity(x, plan, E)
    # identity experts: combine with weight 1/k must reproduce x
    w = jnp.full((T, k), 1.0 / k)
    y = D.combine_capacity(buf, plan, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_capacity_drops_overflow():
    T, E, k = 16, 2, 1
    ids = jnp.zeros((T, k), jnp.int32)  # all tokens to expert 0
    C = 8
    plan = D.make_capacity_plan(ids, E, C)
    assert int(plan.keep.sum()) == C
    assert int(plan.load[0]) == T  # pre-drop load recorded


def test_slot_priority_top1_survives():
    """Top-1 assignments fill before top-2 under overflow (slot-major)."""
    T, E = 8, 2
    ids = jnp.stack([jnp.zeros(T, jnp.int32), jnp.ones(T, jnp.int32)], axis=1)
    ids = ids.at[:, 1].set(0)  # everyone's slot-0 AND slot-1 -> expert 0
    plan = D.make_capacity_plan(ids, E, capacity=8)
    # all 8 slot-0 entries kept; all slot-1 dropped
    assert bool(plan.keep[:, 0].all())
    assert not bool(plan.keep[:, 1].any())


def test_ragged_roundtrip():
    T, E, k, d = 40, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
    ids = _random_assignment(T, E, k, seed=1)
    plan = D.make_ragged_plan(ids, E)
    xs = D.dispatch_ragged(x, plan)
    assert xs.shape == (T * k, d)
    # group sizes count assignments
    assert int(plan.group_sizes.sum()) == T * k
    # identity experts + weights 1/k reproduces x
    y = D.combine_ragged(xs, plan, jnp.full((T, k), 1.0 / k))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_ragged_rows_sorted_by_expert():
    T, E, k = 64, 4, 2
    ids = _random_assignment(T, E, k, seed=2)
    plan = D.make_ragged_plan(ids, E)
    flat = np.asarray(ids).reshape(-1)
    sorted_eids = flat[np.asarray(plan.sort_idx)]
    assert (np.diff(sorted_eids) >= 0).all()


@settings(max_examples=30, deadline=None)
@given(T=st.integers(1, 50), E=st.sampled_from([2, 4, 8]), k=st.integers(1, 3),
       tile=st.sampled_from([4, 8, 16]))
def test_pad_to_tiles_properties(T, E, k, tile):
    k = min(k, E)
    ids = _random_assignment(T, E, k, seed=T * 31 + E)
    x = jax.random.normal(jax.random.PRNGKey(T), (T * k, 4))
    plan = D.make_ragged_plan(ids, E)
    tiled = D.pad_to_tiles(x, plan.group_sizes, tile, E)
    # round trip
    back = D.unpad_tiles(tiled.x, tiled)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
    # every valid row's tile is owned by its expert
    dest = np.asarray(tiled.dest)
    tg = np.asarray(tiled.tile_group)
    sorted_eid = np.repeat(np.arange(E), np.asarray(plan.group_sizes))
    assert (tg[dest // tile] == sorted_eid).all()
    # padding rows are flagged invalid
    assert int(np.asarray(tiled.row_valid).sum()) == T * k


def test_capacity_is_tile_aligned():
    assert D.expert_capacity(100, 8, 2, 1.25) % 8 == 0
    assert D.expert_capacity(1, 128, 2, 1.0) >= 8
