"""Fused expert-FFN backward (ISSUE 3): the dX / grouped-dW Pallas kernels
wired into ``ops.fused_grouped_ffn``'s custom_vjp.

Acceptance: jax.grad through the fused op matches a per-expert einsum oracle
for all four activations, tail hidden tiles (H % bh != 0), variable ragged
group sizes (incl. empty groups) and bf16 inputs — with no two-pass
recompute: the whole fwd+bwd is three pallas_calls and materializes no
(M, H) intermediate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.kernels import ops

ACTS = [("swiglu", True), ("gelu", False), ("rwkv", False), ("silu", False)]


def _setup(E, K, H, N, gated, dtype=jnp.float32, seed=0, gs=None, total=96):
    rng = np.random.default_rng(seed)
    if gs is None:
        gs = rng.multinomial(total, np.ones(E) / E)
    gs = np.asarray(gs, np.int32)
    x = jnp.asarray(rng.normal(size=(int(gs.sum()), K)), dtype)
    ws = tuple(jnp.asarray(rng.normal(size=(E, K, H)) * 0.2, dtype)
               for _ in range(2 if gated else 1))
    wo = jnp.asarray(rng.normal(size=(E, H, N)) * 0.2, dtype)
    return x, ws, wo, gs


def _oracle(x, ws, wo, gs, act):
    """Per-expert dense einsum in f32 — the ground truth the kernels chase.

    ``gs`` is a concrete numpy array, so the group slices are static.
    """
    outs, o = [], 0
    for e, n in enumerate(gs):
        xe = x[o:o + int(n)].astype(jnp.float32)
        if act == "swiglu":
            h = jax.nn.silu(xe @ ws[0][e].astype(jnp.float32))
            h = h * (xe @ ws[1][e].astype(jnp.float32))
        else:
            h = fmoe._act(xe @ ws[0][e].astype(jnp.float32), act)
        outs.append(h @ wo[e].astype(jnp.float32))
        o += int(n)
    return jnp.concatenate(outs, axis=0)


def _grads(loss, x, ws, wo):
    return jax.tree.leaves(jax.grad(loss, argnums=(0, 1, 2))(x, ws, wo))


def _check_grads(x, ws, wo, gs, act, *, bm=8, bh=16, rtol=2e-4, atol=2e-4):
    gs_j = jnp.asarray(gs)

    def l_fused(x, ws, wo):
        return (ops.fused_grouped_ffn(x, ws, wo, gs_j, act, bm, bh) ** 2).sum()

    def l_ref(x, ws, wo):
        return (_oracle(x, ws, wo, gs, act) ** 2).sum()

    for a, b in zip(_grads(l_fused, x, ws, wo), _grads(l_ref, x, ws, wo)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("act,gated", ACTS)
def test_grad_matches_einsum_oracle(act, gated):
    x, ws, wo, gs = _setup(4, 16, 32, 24, gated, seed=1)
    _check_grads(x, ws, wo, gs, act)


@pytest.mark.parametrize("act,gated", ACTS)
def test_grad_tail_hidden_tile(act, gated):
    """H % bh != 0: the masked tail tile must not poison any of dX/dW."""
    x, ws, wo, gs = _setup(4, 16, 40, 24, gated, seed=2)  # 40 % 16 == 8
    _check_grads(x, ws, wo, gs, act)


def test_grad_ragged_group_sizes():
    """Variable sizes with empty groups: empty experts get exactly zero dW."""
    gs = np.asarray([0, 37, 0, 5, 22], np.int32)
    x, ws, wo, _ = _setup(5, 16, 32, 24, True, seed=3, gs=gs)
    _check_grads(x, ws, wo, gs, "swiglu")
    g = jax.grad(lambda ws: (ops.fused_grouped_ffn(
        x, ws, wo, jnp.asarray(gs), "swiglu", 8, 16) ** 2).sum())(ws)
    for dw in g:
        assert np.all(np.asarray(dw[0]) == 0) and np.all(np.asarray(dw[2]) == 0)


def test_grad_bf16_inputs_f32_acc():
    x, ws, wo, gs = _setup(3, 16, 32, 16, True, dtype=jnp.bfloat16, seed=4,
                           total=64)
    gs_j = jnp.asarray(gs)
    g = jax.grad(lambda x, ws, wo: (ops.fused_grouped_ffn(
        x, ws, wo, gs_j, "swiglu", 8, 16).astype(jnp.float32) ** 2).sum(),
        argnums=(0, 1, 2))(x, ws, wo)
    for a in jax.tree.leaves(g):
        assert a.dtype == jnp.bfloat16, a.dtype  # grads land at param dtype
    xf, wsf, wof = (x.astype(jnp.float32),
                    tuple(w.astype(jnp.float32) for w in ws),
                    wo.astype(jnp.float32))
    ref = _grads(lambda x, ws, wo: (_oracle(x, ws, wo, gs, "swiglu") ** 2).sum(),
                 xf, wsf, wof)
    for a, b in zip(jax.tree.leaves(g), ref):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b),
                                   rtol=1e-1, atol=1e-1)


def test_no_two_pass_recompute_in_backward():
    """fwd+bwd = exactly three pallas_calls (fwd, dX, dW) and no (M, H)
    intermediate — the two-pass fallback (5 grouped GEMMs + ragged_dots)
    is gone from the backward."""
    E, K, H, N = 4, 16, 40, 24
    x, ws, wo, gs = _setup(E, K, H, N, True, seed=5)
    M = x.shape[0]
    gs_j = jnp.asarray(gs)
    jaxpr = jax.make_jaxpr(jax.grad(lambda x, ws, wo: (ops.fused_grouped_ffn(
        x, ws, wo, gs_j, "swiglu", 8, 16) ** 2).sum(), argnums=(0, 1, 2)))(
        x, ws, wo)
    assert str(jaxpr).count("pallas_call") == 3
    assert "ragged_dot" not in str(jaxpr)
    hidden = {tuple(v.aval.shape) for eqn in jaxpr.jaxpr.eqns
              for v in eqn.outvars if hasattr(v.aval, "shape")
              and len(v.aval.shape) == 2 and v.aval.shape[1] == H
              and v.aval.shape[0] >= M}
    assert not hidden, hidden


def test_aligned_skips_pad_gather_round_trip():
    """Equal tile-aligned groups: same numbers, no (M, .) gather/scatter in
    the jaxpr (the pad_to_tiles/dest round-trip is skipped)."""
    E, n, K, H, N = 3, 16, 16, 32, 16  # n % bm == 0
    rng = np.random.default_rng(6)
    gs = jnp.full((E,), n, jnp.int32)
    x = jnp.asarray(rng.normal(size=(E * n, K)), jnp.float32)
    ws = tuple(jnp.asarray(rng.normal(size=(E, K, H)) * 0.2, jnp.float32)
               for _ in range(2))
    wo = jnp.asarray(rng.normal(size=(E, H, N)) * 0.2, jnp.float32)

    def loss(aligned):
        return lambda x, ws, wo: (ops.fused_grouped_ffn(
            x, ws, wo, gs, "swiglu", 8, 16, aligned) ** 2).sum()

    np.testing.assert_allclose(np.asarray(loss(True)(x, ws, wo)),
                               np.asarray(loss(False)(x, ws, wo)), rtol=1e-5)
    for a, b in zip(_grads(loss(True), x, ws, wo),
                    _grads(loss(False), x, ws, wo)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)
    txt = str(jax.make_jaxpr(jax.grad(loss(True), argnums=(0, 1, 2)))(x, ws, wo))
    assert "gather" not in txt and "scatter" not in txt
    # grouped_matmul honors the same flag
    ya = ops.grouped_matmul(x, ws[0], gs, "pallas", 8, True)
    yu = ops.grouped_matmul(x, ws[0], gs, "pallas", 8, False)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yu), rtol=1e-6)


@pytest.mark.parametrize("dispatch", ["ragged", "capacity"])
def test_fused_impl_grads_in_moe_layer(dispatch):
    """impl="fused" through fmoe_apply (ragged AND capacity dispatch):
    forward and parameter grads match the einsum expert_fn."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_expert_hidden=48,
                    dispatch=dispatch)
    p = fmoe.fmoe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))

    def loss(impl):
        return lambda p: (fmoe.fmoe_apply(p, x, cfg, impl=impl)[0] ** 2).sum()

    y0, _ = fmoe.fmoe_apply(p, x, cfg, impl="einsum")
    y1, _ = fmoe.fmoe_apply(p, x, cfg, impl="fused")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-5,
                               atol=2e-5)
    g0 = jax.grad(loss("einsum"))(p)
    g1 = jax.grad(loss("fused"))(p)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-4)
