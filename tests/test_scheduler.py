"""Continuous batching: staggered requests through shared decode batches must
reproduce each request's isolated greedy generation exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.scheduler import ContinuousBatcher, Request
from repro.launch.serve import generate
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _isolated(params, cfg, prompt, n):
    seq = generate(params, cfg, jnp.asarray(prompt)[None], steps=n,
                   cache_len=64)
    return np.asarray(seq[0, len(prompt):]).tolist()


def test_batched_equals_isolated(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 9, 3)]
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]

    sched = ContinuousBatcher(params, cfg, max_batch=2, cache_len=64)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)

    for r, p in zip(reqs, prompts):
        expect = _isolated(params, cfg, p, 6)
        assert r.out == expect, (r.uid, r.out, expect)


def test_slots_reused_and_staggered_arrivals(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    sched = ContinuousBatcher(params, cfg, max_batch=2, cache_len=64)
    first = Request(0, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new=3)
    sched.submit(first)
    sched.step()  # first running alone
    late = Request(1, rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                   max_new=5)
    sched.submit(late)  # arrives mid-flight
    sched.run()
    assert first.done and late.done
    assert first.out == _isolated(params, cfg, first.prompt, 3)
    assert late.out == _isolated(params, cfg, late.prompt, 5)


def test_eos_frees_slot(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    ref = _isolated(params, cfg, prompt, 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    req = Request(0, prompt, max_new=8)
    sched = ContinuousBatcher(params, cfg, max_batch=1, cache_len=64,
                              eos_id=int(eos))
    sched.submit(req)
    sched.run()
    assert req.done and req.out == ref[:3]
