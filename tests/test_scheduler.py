"""Continuous batching engine: staggered requests through shared decode
batches must reproduce each request's isolated greedy generation exactly;
the paged KV cache must be bitwise identical to the contiguous ring; a
placement replan mid-stream must be invisible in the token stream."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dist_utils as du
from repro.configs import get_config, reduced
from repro.launch.scheduler import ContinuousBatcher
from repro.launch.serve import generate
from repro.launch.serve_api import Completion, Request, ServeConfig
from repro.models import lm


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("smollm-360m"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _isolated(params, cfg, prompt, n):
    seq = generate(params, cfg, jnp.asarray(prompt)[None], steps=n,
                   cache_len=64)
    return np.asarray(seq[0, len(prompt):]).tolist()


def _by_id(batcher):
    return {c.request_id: c.tokens for c in batcher.completions}


def test_batched_equals_isolated(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 9, 3)]

    sched = ContinuousBatcher(params, cfg, max_batch=2, cache_len=64)
    for i, p in enumerate(prompts):
        sched.submit(Request(id=i, prompt=p, max_new_tokens=6))
    sched.run()
    out = _by_id(sched)
    assert sorted(out) == [0, 1, 2]

    for i, p in enumerate(prompts):
        expect = _isolated(params, cfg, p, 6)
        assert out[i] == expect, (i, out[i], expect)


def test_slots_reused_and_staggered_arrivals(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    sched = ContinuousBatcher(params, cfg, max_batch=2, cache_len=64)
    first = Request(id=0, prompt=rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=3)
    sched.submit(first)
    sched.step()  # first running alone
    late = Request(id=1, prompt=rng.integers(
        0, cfg.vocab_size, 7).astype(np.int32), max_new_tokens=5)
    sched.submit(late)  # arrives mid-flight
    sched.run()
    out = _by_id(sched)
    assert out[0] == _isolated(params, cfg, first.prompt, 3)
    assert out[1] == _isolated(params, cfg, late.prompt, 5)
    # the serving timeline is filled in and ordered
    for c in sched.completions:
        assert c.queued <= c.first_token <= c.done
        assert len(c.token_times) == len(c.tokens)
        assert all(l >= 0 for l in c.latencies)


def test_eos_frees_slot(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    ref = _isolated(params, cfg, prompt, 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    sched = ContinuousBatcher(params, cfg, max_batch=1, cache_len=64,
                              eos_id=int(eos))
    sched.submit(Request(id=0, prompt=prompt, max_new_tokens=8))
    sched.run()
    assert _by_id(sched)[0] == ref[:3]


# -- paged KV cache ----------------------------------------------------------


def _mixed_stream(cfg, n=9, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(id=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       rng.randint(3, 20)).astype(np.int32),
                    max_new_tokens=int(rng.randint(2, 12)), arrival=0.0)
            for i in range(n)]


def _run_stream(params, cfg, scfg, reqs):
    b = ContinuousBatcher(params, cfg, scfg)
    for r in reqs:
        b.submit(Request(id=r.id, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens, arrival=0.0))
    b.run()
    return _by_id(b), b


def test_paged_matches_ring_bitwise(setup):
    """The central paged-cache claim: decoding through the block-table view
    over the shared pool is bitwise identical to the contiguous per-slot
    ring, across admissions, retires, slot reuse and partial tail blocks."""
    cfg, params = setup
    reqs = _mixed_stream(cfg)
    paged, bp = _run_stream(params, cfg, ServeConfig(
        slots=3, max_len=48, block_size=8, paged=True), reqs)
    ring, br = _run_stream(params, cfg, ServeConfig(
        slots=3, max_len=48, block_size=8, paged=False), reqs)
    assert bp.paged and not br.paged
    assert sorted(paged) == sorted(ring) == list(range(len(reqs)))
    for i in paged:
        assert paged[i] == ring[i], (i, paged[i], ring[i])


def test_paged_mla_matches_ring_bitwise():
    """Same bitwise claim for the MLA (latent) cache family."""
    cfg = reduced(get_config("deepseek-v2-236b"), num_layers=2, d_model=64)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_stream(cfg, n=5, seed=3)
    paged, _ = _run_stream(params, cfg, ServeConfig(
        slots=2, max_len=40, block_size=8, paged=True), reqs)
    ring, _ = _run_stream(params, cfg, ServeConfig(
        slots=2, max_len=40, block_size=8, paged=False), reqs)
    for i in paged:
        assert paged[i] == ring[i], (i, paged[i], ring[i])


def test_block_reuse_under_pool_pressure(setup):
    """A pool too small for all requests at once: admission blocks FIFO,
    retired requests' blocks are recycled, every request still reproduces
    its isolated generation, and the pool drains back to fully free."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    # each request needs ceil((5+6)/8) = 2 blocks; 3 usable blocks total
    # (num_blocks=5 minus the 2 reserved) so two can never fly together
    scfg = ServeConfig(slots=2, max_len=16, block_size=8, num_blocks=5)
    b = ContinuousBatcher(params, cfg, scfg)
    assert b.allocator.free_blocks == 3
    for i, p in enumerate(prompts):
        b.submit(Request(id=i, prompt=p, max_new_tokens=6))
    b.run()
    out = _by_id(b)
    for i, p in enumerate(prompts):
        assert out[i] == _isolated(params, cfg, p, 6)
    assert b.allocator.free_blocks == 3  # every block returned
    assert (b.tables == 0).all()  # tables reset to the null block


def test_submit_rejects_over_cap(setup):
    cfg, params = setup
    b = ContinuousBatcher(params, cfg, ServeConfig(slots=1, max_len=16))
    with pytest.raises(ValueError, match="exceeds max_len"):
        b.submit(Request(id=0, prompt=np.zeros(12, np.int32),
                         max_new_tokens=8))


def test_static_policy_head_of_line_blocks(setup):
    """policy="static" admits only at whole-batch boundaries: short
    requests wait on the batch's longest, costing ticks the continuous
    policy saves — the same decode path, so tokens stay identical."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(4)]
    lens = [2, 8, 2, 8]

    def drive(policy):
        b = ContinuousBatcher(params, cfg, ServeConfig(
            slots=2, max_len=16, block_size=8, policy=policy))
        for i, (p, n) in enumerate(zip(prompts, lens)):
            b.submit(Request(id=i, prompt=p, max_new_tokens=n))
        b.run()
        return _by_id(b), b.ticks

    cont, t_cont = drive("continuous")
    stat, t_stat = drive("static")
    assert cont == stat  # identical decode path, identical tokens
    assert t_stat > t_cont  # head-of-line blocking costs real ticks


# -- serving API -------------------------------------------------------------


def test_scheduler_request_reexport_deprecated():
    import repro.launch.scheduler as scheduler
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cls = scheduler.Request
    assert cls is Request
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_serve_config_from_args():
    from types import SimpleNamespace
    args = SimpleNamespace(batch=4, slots=None, block_size=32, max_len=None,
                           policy="static", replan_every=None, mesh=None)
    scfg = ServeConfig.from_args(args)
    assert scfg.slots == 4  # --batch maps onto slots when --slots absent
    assert scfg.block_size == 32 and scfg.policy == "static"
    assert scfg.max_len == 256 and scfg.replan_every == 0  # defaults kept
    args.slots = 16
    assert ServeConfig.from_args(args).slots == 16  # explicit slots wins
    with pytest.raises(ValueError, match="policy"):
        ServeConfig(policy="batched")


def test_completion_latencies():
    c = Completion(request_id=0, tokens=[1, 2, 3], prompt_len=4, queued=10.0,
                   first_token=10.5, done=10.7,
                   token_times=[10.5, 10.6, 10.7])
    assert c.ttft == pytest.approx(0.5)
    assert c.latencies == pytest.approx([0.5, 0.1, 0.1])


# -- mid-stream replan (fake devices) ----------------------------------------


def test_replan_mid_stream_bitwise():
    """Switching the expert placement between decode ticks — live param
    migration + re-jit, exactly what the online replan path does — must
    leave every decoded token bitwise identical: the serving decode dist is
    pinned to the psum mode, whose per-slot combine is layout-invariant."""
    du.run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.models import lm
        from repro.launch.scheduler import ContinuousBatcher
        from repro.launch.serve_api import Request, ServeConfig
        from repro.placement import identity_per_layer
        from repro.placement.plan import ExpertPlacement, per_layer_placement

        cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
        E, L = cfg.moe.num_experts, cfg.num_layers
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        reqs = [dict(id=i,
                     prompt=rng.randint(0, cfg.vocab_size,
                                        5 + (i % 6)).astype(np.int32),
                     max_new_tokens=4 + (i % 5)) for i in range(8)]
        # rank-swapping permutation + 2 shadowed hot experts per layer:
        # both mechanisms a serve-time plan uses (E=4 on 2 ranks)
        plan = per_layer_placement([
            ExpertPlacement(E, 2, (1, 3, 0, 2), num_shadow=2),
            ExpertPlacement(E, 2, (2, 0, 3, 1), num_shadow=2)])

        def run(switch_at):
            scfg = ServeConfig(slots=4, max_len=24, block_size=8, mesh="1x2")
            b = ContinuousBatcher(params, cfg, scfg,
                                  placement=identity_per_layer(E, 2, L))
            for r in reqs:
                b.submit(Request(arrival=0.0, **r))
            while b.queue or any(s is not None for s in b.slots):
                b.step()
                if switch_at is not None and b.ticks == switch_at:
                    b.apply_placement(plan)
            return {c.request_id: c.tokens for c in b.completions}

        base = run(None)
        moved = run(3)
        assert sorted(base) == sorted(moved) == list(range(8))
        for i in base:
            assert base[i] == moved[i], (i, base[i], moved[i])
        print("BITWISE", sum(len(v) for v in base.values()))
        """, devices=2)
