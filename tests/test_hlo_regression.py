"""HLO-level regressions (ISSUE 3): the properties the kernels/schedule buy
must survive XLA's optimizer, not just the jaxpr.

* A jitted fused fwd+bwd step compiles to HLO with no (M, H)-shaped
  intermediate — the hidden activation/gradient live only as VMEM tiles
  inside the three pallas_calls.  The two-pass program is the oracle that
  the check itself can see the hidden when it IS materialized.
* With ``overlap_chunks > 1`` the distributed MoE layer's HLO contains no
  blocking ``all-to-all`` at all (payload AND counts exchanges are
  ppermute-decomposed), only async-schedulable ``collective-permute``s.

Everything lowers on CPU via ``.lower().compile().as_text()``; the
multi-device case runs in a subprocess with fake devices (same pattern as
tests/test_distributed.py).
"""
import os
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

E, K, H, N, BM, BH = 4, 16, 40, 24, 8, 16


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    gs = np.asarray([30, 26, 20, 20], np.int32)
    x = jnp.asarray(rng.normal(size=(int(gs.sum()), K)), jnp.float32)
    ws = tuple(jnp.asarray(rng.normal(size=(E, K, H)) * 0.2, jnp.float32)
               for _ in range(2))
    wo = jnp.asarray(rng.normal(size=(E, H, N)) * 0.2, jnp.float32)
    return x, ws, wo, jnp.asarray(gs)


def _hidden_rows(hlo: str) -> list[int]:
    """Row counts of every 2-D (rows, H) tensor in the HLO text."""
    return [int(m.group(1)) for m in re.finditer(rf"\[(\d+),{H}\]", hlo)]


def _compiled(loss, x, ws, wo):
    step = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
    return step.lower(x, ws, wo).compile().as_text()


def test_fused_step_hlo_has_no_hidden_intermediate():
    x, ws, wo, gs = _setup()
    M = x.shape[0]
    hlo = _compiled(lambda x, ws, wo: (ops.fused_grouped_ffn(
        x, ws, wo, gs, "swiglu", BM, BH) ** 2).sum(), x, ws, wo)
    rows = [r for r in _hidden_rows(hlo) if r >= M]
    assert not rows, f"(M, H)-shaped intermediates in optimized HLO: {rows}"
    # oracle: the two-pass step DOES materialize (M_padded, H) — proves the
    # check can see a hidden intermediate when one exists
    hlo2 = _compiled(lambda x, ws, wo: (ops.ffn_two_pass(
        x, ws, wo, gs, "swiglu", "pallas", BM) ** 2).sum(), x, ws, wo)
    assert any(r >= M for r in _hidden_rows(hlo2)), "oracle lost the hidden"


def test_ragged_moe_hlo_no_blocking_a2a_no_hidden():
    """The ragged (dropless) exchange inherits both HLO properties:

    * overlap_chunks > 1 -> counts AND payload exchanges are ppermute-
      decomposed, no blocking ``all-to-all`` survives XLA;
    * impl="fused" -> the per-rank fwd+bwd step materializes no 2-D
      (rows, H) tensor at the exchange-buffer row count (mp*bound) or
      above — hidden tiles stay (bm, bh) with bm=128 < mp*bound here.
      The two-pass program is the oracle that the check can see one.
    """
    script = """
        import re
        import jax
        from repro.configs.base import MoEConfig
        from repro.core import fmoe
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        H = 40
        cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=H,
                        dispatch="ragged")
        params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16))
        MB = 4 * 32 * 2  # mp * t_local * top_k = exchange-buffer rows
        serial = fmoe.DistConfig(mesh, ("data", "model"))
        piped = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=2)
        def hlo(dist, impl, grad=False):
            f = lambda p, x_: fmoe.fmoe_apply(p, x_, cfg, dist=dist,
                                              impl=impl)[0]
            if grad:
                f = jax.grad(lambda p, x_: (fmoe.fmoe_apply(
                    p, x_, cfg, dist=dist, impl=impl)[0] ** 2).sum())
            with mesh:
                return jax.jit(f).lower(params, x).compile().as_text()
        t_piped = hlo(piped, "fused")
        t_serial = hlo(serial, "fused")
        assert "all-to-all" in t_serial, "oracle: serial ragged path must a2a"
        assert "all-to-all" not in t_piped, "blocking all-to-all survived"
        assert "collective-permute" in t_piped
        rows = lambda t: [int(m.group(1))
                          for m in re.finditer(r"\\[(\\d+),%d\\]" % H, t)]
        big = [r for r in rows(hlo(serial, "fused", grad=True)) if r >= MB]
        assert not big, f"(rows, H) intermediates in fused ragged HLO: {big}"
        big2 = [r for r in rows(hlo(serial, "pallas", grad=True)) if r >= MB]
        assert big2, "oracle lost the two-pass hidden"
        print("RAGGED_HLO_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RAGGED_HLO_OK" in out.stdout


def test_pipelined_hlo_collectives_bracket_expert_gemms():
    """ROADMAP follow-on (ISSUE 5): the §5.2 schedule's value is exchange /
    compute overlap, so the *structure* of the optimized HLO must show it —
    collective-permutes must actually bracket the expert GEMM fusions, not
    merely replace the blocking all-to-all.

    On backends that async-schedule (TPU), every chunk's expert GEMM must
    sit between a ``collective-permute-start`` and its matching ``-done``.
    XLA:CPU lowers synchronous ``collective-permute``s, where the same
    interleaving shows as op order: with overlap_chunks=2 the instruction
    stream must contain >= 2 separate expert-GEMM runs each flanked by
    collective-permutes on both sides (S0 | S1 C0 R0 | C1 R1)."""
    import dist_utils as du

    out = du.run("""
        import re
        import jax
        from repro.configs.base import MoEConfig
        from repro.core import fmoe
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32,
                        capacity_factor=2.0)
        params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        piped = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=2)
        with mesh:
            txt = jax.jit(lambda p, x: fmoe.fmoe_apply(
                p, x, cfg, dist=piped)[0]).lower(params, x).compile().as_text()
        lines = txt.splitlines()
        # expert GEMMs: batched (E_local, rows, ·) dots — 3-D outputs.  The
        # router GEMM and combine einsum are 2-D, so they don't count.
        gemm = [i for i, l in enumerate(lines)
                if re.search(r"= \\S+\\[\\d+,\\d+,\\d+\\]\\S* dot\\(", l)]
        assert gemm, "no expert GEMMs found in optimized HLO"
        starts = [i for i, l in enumerate(lines)
                  if "collective-permute-start" in l]
        if starts:  # async backend: GEMMs inside a start/done window
            dones = [i for i, l in enumerate(lines)
                     if "collective-permute-done" in l]
            assert any(s < g < d for g in gemm
                       for s, d in zip(starts, dones)), \\
                "no expert GEMM scheduled inside a start/done window"
        else:  # sync lowering: bracket structure via instruction order
            cp = [i for i, l in enumerate(lines)
                  if re.search(r"= \\S+ collective-permute\\(", l)]
            assert cp, "no collective-permutes in pipelined HLO"
            # count maximal GEMM runs with a collective-permute on both sides
            events = sorted([(i, "cp") for i in cp] + [(i, "g") for i in gemm])
            runs, seen_cp, in_run, bracketed = 0, False, False, 0
            for _, kind in events:
                if kind == "cp":
                    if in_run:
                        bracketed += 1
                        in_run = False
                    seen_cp = True
                elif seen_cp:
                    in_run = True
            assert bracketed >= 2, (
                f"expected >= 2 expert-GEMM runs bracketed by collective-"
                f"permutes (overlap_chunks=2), found {bracketed}")
        print("BRACKET_OK")
    """, devices=4)
    assert "BRACKET_OK" in out


def test_hier_inter_node_collective_only_on_node_axis():
    """ISSUE 7 tentpole property, at the HLO level: on the (data, node,
    model) mesh the two-level ragged exchange must keep the full-size
    payload on the node-local axis — the only collectives whose replica
    groups cross the node boundary are the slim inter legs, and their
    bytes are exactly the counter's wire_bytes_inter.  The flat exchange
    on the same mesh is the oracle: one 8-wide group, everything crosses.
    """
    import dist_utils as du

    out = du.run("""
    import re
    import jax
    import dist_utils as du
    from repro.core import fmoe
    from repro.launch.roofline import collective_bytes
    env = du.moe_env(dispatch="ragged", capacity_factor=1.25)
    mesh = du.make_mesh(1, 4, node=2)  # ranks node-major: node = rank // 4
    flat = fmoe.DistConfig(mesh, ("data", "node", "model"),
                           expert_axis=("node", "model"))
    hier = flat._replace(node_axis="node", inter_bound=24)

    def wire_defs(dist):
        with mesh:
            fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, env.cfg,
                                                      dist=dist))
            txt = fn.lower(env.params, env.x).compile().as_text()
        return [l for l in txt.splitlines()
                if re.search(r" (all-to-all|collective-permute)\\(", l)]

    INNER = "replica_groups={{0,1,2,3},{4,5,6,7}}"   # node-local axis
    NODE = "replica_groups={{0,4},{1,5},{2,6},{3,7}}"  # crosses nodes
    lines = wire_defs(hier)
    assert lines and all((INNER in l) or (NODE in l) for l in lines), (
        "exchange collective on neither mesh axis:\\n" + "\\n".join(lines))
    cross = [l for l in lines if NODE in l]
    got = sum(collective_bytes(l).get("all-to-all", 0) for l in cross)
    # slim legs only: 2 payload legs x n_nodes*IB rows x d f32 + the
    # 8-int32 counts leg == the device counter's wire_bytes_inter
    with mesh:
        _, m = jax.jit(lambda p, x: fmoe.fmoe_apply(
            p, x, env.cfg, dist=hier))(env.params, env.x)
    want = 4 * (2 * 2 * 24 * 32 + 8)
    assert got == want == float(m.obs.wire_bytes_inter), (got, want)
    # oracle: the flat exchange's every payload crosses in one 8-wide group
    fl = wire_defs(flat)
    assert fl and all("replica_groups={{0,1,2,3,4,5,6,7}}" in l for l in fl)
    print("HIER_HLO_OK")
    """, devices=8)
    assert "HIER_HLO_OK" in out


def test_pipelined_moe_hlo_has_no_blocking_all_to_all():
    script = """
        import jax
        from repro.configs.base import MoEConfig
        from repro.core import fmoe
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32,
                        capacity_factor=2.0)
        params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        serial = fmoe.DistConfig(mesh, ("data", "model"))
        piped = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=2)
        def hlo(dist):
            with mesh:
                return jax.jit(lambda p, x: fmoe.fmoe_apply(
                    p, x, cfg, dist=dist)[0]).lower(params, x).compile().as_text()
        t_piped, t_serial = hlo(piped), hlo(serial)
        assert "all-to-all" in t_serial, "oracle: serial path must a2a"
        assert "all-to-all" not in t_piped, "blocking all-to-all survived"
        assert "collective-permute" in t_piped
        print("PIPELINED_HLO_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINED_HLO_OK" in out.stdout
