"""Consolidated multi-rank differential-test harness (ISSUE 5 satellite).

The single source of truth for everything the distributed tests used to
duplicate per module:

* :func:`run` — the subprocess runner.  Multi-device tests execute scripts
  under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in a child
  process so the main pytest process keeps its single CPU device (the
  dry-run contract in tests/conftest.py).  The child's ``PYTHONPATH``
  includes this directory, so scripts ``import dist_utils`` and reuse the
  helpers below *inside* the subprocess.
* mesh / MoE-layer builders — :func:`make_mesh`, :func:`moe_env`.
* the single-rank oracle — :func:`oracle` (``fmoe_apply`` without ``dist``):
  every distributed mode must reproduce it, the ragged/fused ones bitwise.
* differential assertions — :func:`assert_close`, :func:`assert_bit_exact`,
  and :func:`assert_grads_match` (expert grads bitwise, router grad to f32
  reassociation tolerance — its GEMM shape differs per sharding).
* the host-level ragged-exchange emulation (:func:`emulate_ragged_exchange`)
  exercising core/dispatch's plan index math without devices.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TESTS = os.path.join(ROOT, "tests")


def run(script: str, devices: int = 8, timeout: int = 560) -> str:
    """Run ``script`` in a subprocess with ``devices`` fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(ROOT, "src"), TESTS])
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def run_cli(argv: list, devices: int = 4, timeout: int = 560, env=None,
            check: bool = True):
    """Run a ``python -m`` CLI (e.g. repro.launch.train) on fake devices.

    ``env`` adds/overrides child environment vars (e.g. ``REPRO_FAULTS``
    for the resilience drills).  ``check=False`` returns the
    CompletedProcess instead of asserting exit 0 — crash drills assert a
    *specific* non-zero code (faults.CRASH_EXIT_CODE)."""
    child = dict(os.environ)
    child["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    child["PYTHONPATH"] = os.path.join(ROOT, "src")
    if env:
        child.update(env)
    out = subprocess.run([sys.executable, "-m"] + argv, capture_output=True,
                         text=True, env=child, timeout=timeout, cwd=ROOT)
    if not check:
        return out
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Script-side builders (used inside the subprocess; need the fake devices)
# ---------------------------------------------------------------------------


def make_mesh(data: int = 2, model: int = 4, node: int = 0):
    """Flat (data, model) mesh, or the (data, node, model) node-major mesh
    of the two-level hierarchy when ``node`` is given."""
    if node:
        return jax.make_mesh((data, node, model), ("data", "node", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def moe_env(*, num_experts: int = 8, top_k: int = 2, d_hidden: int = 64,
            d_model: int = 32, tokens=(8, 16), dispatch: str = "capacity",
            capacity_factor: float = 8.0, seed: int = 0,
            **cfg_kw) -> SimpleNamespace:
    """One MoE layer + inputs: the shared fixture of every differential test.

    Defaults match the historical test setup (generous capacity_factor so
    the capacity modes don't drop and stay comparable to dropless paths).
    """
    from repro.configs.base import MoEConfig
    from repro.core import fmoe

    cfg = MoEConfig(num_experts=num_experts, top_k=top_k,
                    d_expert_hidden=d_hidden, capacity_factor=capacity_factor,
                    dispatch=dispatch, **cfg_kw)
    params = fmoe.fmoe_init(jax.random.PRNGKey(seed), d_model, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (*tokens, d_model))
    return SimpleNamespace(cfg=cfg, params=params, x=x)


def skew_router(env, hot=(10.0, 5.0)) -> SimpleNamespace:
    """The env with a router forced to route every (positive) token to the
    first len(hot) experts — the Zipf-skew / zero-token-rank case."""
    w = np.zeros((env.x.shape[-1], env.cfg.num_experts), np.float32)
    for e, v in enumerate(hot):
        w[:, e] = v
    params = {**env.params,
              "router": {**env.params["router"], "w": jnp.asarray(w)}}
    return SimpleNamespace(cfg=env.cfg, params=params,
                           x=jnp.abs(env.x) + 0.1)


# the router sweep axis: every variant must pass the same dispatch x impl x
# dist x overlap differential sweep (single-rank oracle, same assertions)
ROUTERS = ("topk", "noisy_topk", "gumbel", "expert_choice", "frozen")


def oracle(env, impl: str = "einsum", params=None, x=None):
    """The single-rank reference: fmoe_apply with no dist."""
    from repro.core import fmoe

    return fmoe.fmoe_apply(params if params is not None else env.params,
                           x if x is not None else env.x, env.cfg, impl=impl)


def oracle_sharded(env, n_shards: int, impl: str = "einsum", params=None,
                   x=None):
    """Shard-wise single-rank reference: fmoe_apply per token shard,
    concatenated back.  This is the oracle for routers whose decision
    depends on the token *population* — expert-choice picks each expert's
    top-C from the tokens it can see, so under token sharding the reference
    routes each shard independently (n_shards = the product of the dist's
    token axes).  With n_shards=1 it degenerates to :func:`oracle`."""
    from repro.core import fmoe

    p = params if params is not None else env.params
    xv = x if x is not None else env.x
    xf = xv.reshape(-1, xv.shape[-1])
    assert xf.shape[0] % n_shards == 0
    shards = xf.reshape(n_shards, -1, xv.shape[-1])
    ys, loads = [], []
    for i in range(n_shards):
        y, m = fmoe.fmoe_apply(p, shards[i], env.cfg, impl=impl)
        ys.append(y)
        loads.append(m.load)
    return (jnp.concatenate(ys, 0).reshape(xv.shape),
            jnp.stack(loads).mean(0))


def dist_apply(env, mesh, dist, params=None, x=None, impl: str = "einsum"):
    """Jitted distributed apply under ``mesh`` (the differential side)."""
    from repro.core import fmoe

    with mesh:
        return jax.jit(lambda p, x_: fmoe.fmoe_apply(
            p, x_, env.cfg, dist=dist, impl=impl))(
                params if params is not None else env.params,
                x if x is not None else env.x)


def layer_grads(env, dist, mesh=None, params=None, impl: str = "einsum",
                aux_weight: float = 0.01):
    """Grads of a scalar loss through the layer ((y**2).mean() + aux).

    ``aux_weight=0.0`` drops the aux term — the bitwise grad comparisons
    use it because the sharded balance loss (pmean of per-shard f·P) is a
    *different function* than the single-rank global one, so its grads
    legitimately diverge beyond rounding."""
    from repro.core import fmoe

    def loss(p):
        y, m = fmoe.fmoe_apply(p, env.x, env.cfg, dist=dist, impl=impl)
        return (y ** 2).mean() + aux_weight * m.aux_loss

    p = params if params is not None else env.params
    if mesh is None:
        return jax.jit(jax.grad(loss))(p)
    with mesh:
        return jax.jit(jax.grad(loss))(p)


def hot_shadow_plan(load, num_ranks: int, num_shadow: int,
                    capacity_scale: float = 1.0):
    """The canonical test plan: shadow the S hottest experts (physical tail),
    keep the owned experts sorted ascending in the front block."""
    from repro.placement import ExpertPlacement

    load = np.asarray(load)
    hot = np.argsort(-load)
    S = num_shadow
    phys = (tuple(int(e) for e in np.sort(hot[S:]))
            + tuple(int(e) for e in hot[:S]))
    return ExpertPlacement(load.size, num_ranks, phys, num_shadow=S,
                           capacity_scale=capacity_scale)


# ---------------------------------------------------------------------------
# Differential assertions
# ---------------------------------------------------------------------------


def assert_close(a, b, tol: float = 1e-5, msg=""):
    err = float(jnp.abs(jnp.asarray(a) - jnp.asarray(b)).max())
    assert err < tol, (msg, err)


def assert_bit_exact(a, b, msg=""):
    a, b = np.asarray(a), np.asarray(b)
    assert (a == b).all(), (msg, float(np.abs(a - b).max()))


def assert_grads_match(g_ref, g_dist, *, bitwise_experts: bool = True,
                       router_atol: float = 1e-6):
    """Expert grads bitwise (same rows, same tile partitioning, same f32
    accumulation order across the exchange); router grad to reassociation
    tolerance (x^T @ dlogits runs at a different GEMM shape per sharding)."""
    for k, v in g_ref["experts"].items():
        a, b = np.asarray(v), np.asarray(g_dist["experts"][k])
        if bitwise_experts:
            np.testing.assert_array_equal(a, b, err_msg=f"experts/{k}")
        else:
            np.testing.assert_allclose(a, b, atol=router_atol,
                                       err_msg=f"experts/{k}")
    for rk in g_ref["router"]:  # w, plus w_noise / w_frozen per router
        np.testing.assert_allclose(np.asarray(g_ref["router"][rk]),
                                   np.asarray(g_dist["router"][rk]),
                                   atol=router_atol, err_msg=f"router/{rk}")
    for l_ref, l_dist in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_dist)):
        assert np.isfinite(np.asarray(l_ref, np.float32)).all()
        assert np.isfinite(np.asarray(l_dist, np.float32)).all()


# ---------------------------------------------------------------------------
# Host-level ragged-exchange emulation (no devices; pure index math)
# ---------------------------------------------------------------------------


def emulate_ragged_exchange(rng, mp, e_local, t, k, bound):
    """Run the full send→exchange→compact pipeline for mp fake ranks on the
    host and return, per rank, the compacted rows + group sizes it computes.

    (The multi-rank *oracle* for core/dispatch's cross-rank plan index math:
    payload rows are (source rank, original row) tags, so tests can verify
    segment structure without running any collective.)
    """
    from repro.core import dispatch as D

    E = mp * e_local
    sends, counts, rows = [], [], []
    for r in range(mp):
        ids = rng.integers(0, E, size=(t * k,))
        order = np.argsort(ids, kind="stable")
        gs = np.bincount(ids, minlength=E)
        xp = D.make_ragged_xplan(jnp.asarray(gs, jnp.int32), t * k, E, mp,
                                 bound)
        # payload rows are (rank, original row index) tags
        payload = np.stack([np.full(t * k, r), order], 1)
        buf = np.full((mp * bound, 2), -1)
        dest = np.asarray(xp.send_dest)
        ok = dest < mp * bound
        buf[dest[ok]] = payload[ok]
        sends.append(buf.reshape(mp, bound, 2))
        counts.append(np.asarray(xp.peer_counts))
        rows.append((ids, order, np.asarray(xp.keep)))
    outs = []
    for r in range(mp):  # the all-to-all: shard s of rank r's recv = rank
        recv = np.stack([sends[s][r] for s in range(mp)])  # s's shard r
        incoming = np.stack([counts[s][r] for s in range(mp)])
        cplan, gs_local = D.ragged_recv_compact(jnp.asarray(incoming,
                                                            jnp.int32), bound)
        compact = np.full((mp * bound, 2), -1)
        cp = np.asarray(cplan)
        ok = cp < mp * bound
        compact[cp[ok]] = recv.reshape(mp * bound, 2)[ok]
        outs.append((compact, np.asarray(gs_local), incoming))
    return rows, outs
