"""Gate network unit + property tests (paper §2.1 Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core.gate import gate_forward, gate_init


def _mk(d=16, E=8, policy="softmax_topk", k=2, renorm=True):
    cfg = MoEConfig(num_experts=E, top_k=k, d_expert_hidden=32,
                    gate_policy=policy, renormalize=renorm)
    params = gate_init(jax.random.PRNGKey(0), d, E)
    return cfg, params


@pytest.mark.parametrize("policy", ["softmax_topk", "topk_softmax"])
def test_gate_shapes_and_ranges(policy):
    cfg, params = _mk(policy=policy)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g = gate_forward(params, x, cfg)
    assert g.expert_ids.shape == (32, 2)
    assert g.combine_weights.shape == (32, 2)
    assert g.probs.shape == (32, 8)
    assert bool(jnp.all((g.expert_ids >= 0) & (g.expert_ids < 8)))
    np.testing.assert_allclose(np.asarray(g.probs.sum(-1)), 1.0, rtol=1e-5)


def test_topk_picks_highest_prob():
    cfg, params = _mk()
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    g = gate_forward(params, x, cfg)
    probs = np.asarray(g.probs)
    ids = np.asarray(g.expert_ids)
    for t in range(64):
        top = set(np.argsort(-probs[t])[:2])
        assert set(ids[t]) == top


def test_renormalized_weights_sum_to_one():
    cfg, params = _mk(renorm=True)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 16))
    g = gate_forward(params, x, cfg)
    np.testing.assert_allclose(np.asarray(g.combine_weights.sum(-1)), 1.0,
                               rtol=1e-5)


def test_slots_are_distinct_experts():
    cfg, params = _mk(k=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 16))
    g = gate_forward(params, x, cfg)
    ids = np.asarray(g.expert_ids)
    for row in ids:
        assert len(set(row.tolist())) == len(row)


@settings(max_examples=25, deadline=None)
@given(T=st.integers(1, 64), E=st.sampled_from([2, 4, 8, 16]),
       k=st.integers(1, 4))
def test_gate_properties(T, E, k):
    k = min(k, E)
    cfg = MoEConfig(num_experts=E, top_k=k, d_expert_hidden=8)
    params = gate_init(jax.random.PRNGKey(0), 8, E)
    x = jax.random.normal(jax.random.PRNGKey(T), (T, 8))
    g = gate_forward(params, x, cfg)
    assert g.expert_ids.shape == (T, k)
    w = np.asarray(g.combine_weights)
    assert (w >= 0).all() and (w <= 1 + 1e-6).all()
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-4)


def test_gate_deterministic_without_rng():
    cfg, params = _mk()
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 16))
    g1 = gate_forward(params, x, cfg)
    g2 = gate_forward(params, x, cfg)
    assert bool(jnp.all(g1.expert_ids == g2.expert_ids))
