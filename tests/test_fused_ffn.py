"""Fused expert-FFN kernel vs the two-pass grouped-GEMM reference.

Acceptance (ISSUE 2): forward and grad match within fp32 tolerance, and the
fused program materializes no (M, H) hidden intermediate — the two GEMMs and
the activation live in one pallas_call with the hidden tile in VMEM.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.kernels import ops

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _setup(E, K, H, N, gated, dtype=jnp.float32, seed=0, total=96):
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.multinomial(total, np.ones(E) / E), jnp.int32)
    M = int(gs.sum())
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    ws = tuple(jnp.asarray(rng.normal(size=(E, K, H)) * 0.1, dtype)
               for _ in range(2 if gated else 1))
    wo = jnp.asarray(rng.normal(size=(E, H, N)) * 0.1, dtype)
    return x, ws, wo, gs


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act,gated", [("swiglu", True), ("gelu", False),
                                       ("rwkv", False)])
def test_fused_matches_two_pass_forward(act, gated, dtype):
    x, ws, wo, gs = _setup(4, 32, 48, 24, gated, dtype)
    y = ops.fused_grouped_ffn(x, ws, wo, gs, act, 8, 16)
    y_ref = ops.ffn_two_pass(x, ws, wo, gs, act, "pallas", 8)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("act,gated", [("swiglu", True), ("gelu", False)])
def test_fused_grad_matches_two_pass(act, gated):
    x, ws, wo, gs = _setup(3, 24, 32, 16, gated, seed=3, total=60)

    def l_fused(x, ws, wo):
        return (ops.fused_grouped_ffn(x, ws, wo, gs, act, 8, 16) ** 2).sum()

    def l_ref(x, ws, wo):
        return (ops.ffn_two_pass(x, ws, wo, gs, act, "pallas", 8) ** 2).sum()

    gk = jax.grad(l_fused, argnums=(0, 1, 2))(x, ws, wo)
    gr = jax.grad(l_ref, argnums=(0, 1, 2))(x, ws, wo)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_fused_tail_hidden_tile():
    """bh not dividing H exercises the masked tail tile (on real TPU the
    out-of-bounds tail reads are garbage; the kernel must zero them)."""
    x, ws, wo, gs = _setup(4, 32, 56, 24, True, seed=5)  # 56 % 16 == 8
    y = ops.fused_grouped_ffn(x, ws, wo, gs, "swiglu", 8, 16)
    y_ref = ops.ffn_two_pass(x, ws, wo, gs, "swiglu", "pallas", 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)


def test_gating_weight_count_must_match_act():
    """Gated ws with act != swiglu would make fwd (kernel ignores wi_up) and
    bwd (two-pass computes silu*up) different functions; a single wi with
    swiglu (the *default* act) would multiply by None mid-trace.  Both
    directions must raise a clear ValueError."""
    x, ws2, wo, gs = _setup(2, 16, 24, 8, True, total=32)
    ws1 = ws2[:1]
    for ws, act in ((ws2, "gelu"), (ws1, "swiglu")):
        for fn in (lambda: ops.fused_grouped_ffn(x, ws, wo, gs, act, 8, 16),
                   lambda: ops.ffn_two_pass(x, ws, wo, gs, act, "pallas", 8)):
            with pytest.raises(ValueError, match="swiglu"):
                fn()


def test_fused_empty_groups():
    gs = jnp.array([0, 10, 0, 6], jnp.int32)
    x, ws, wo, _ = _setup(4, 32, 48, 24, True, total=16)
    y = ops.fused_grouped_ffn(x, ws, wo, gs, "swiglu", 8, 16)
    y_ref = ops.ffn_two_pass(x, ws, wo, gs, "swiglu", "pallas", 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)


def test_no_hidden_materialization():
    """The fused jaxpr holds no (M_padded, H) intermediate: the hidden
    activation exists only as VMEM tiles inside the single pallas_call.  The
    two-pass jaxpr (oracle for the check itself) does materialize it."""
    E, K, H, N, bm = 4, 32, 48, 24, 8
    x, ws, wo, gs = _setup(E, K, H, N, True)

    def shapes_of(fn):
        jaxpr = jax.make_jaxpr(fn)(x, ws, wo)
        shapes = set()
        for eqn in jaxpr.jaxpr.eqns:
            for v in eqn.outvars:
                if hasattr(v.aval, "shape"):
                    shapes.add(tuple(v.aval.shape))
        return jaxpr, shapes

    jaxpr_f, fused_shapes = shapes_of(
        lambda x, ws, wo: ops.fused_grouped_ffn(x, ws, wo, gs, "swiglu", bm, 16))
    _, ref_shapes = shapes_of(
        lambda x, ws, wo: ops.ffn_two_pass(x, ws, wo, gs, "swiglu", "pallas", bm))
    hidden = {s for s in ref_shapes if len(s) == 2 and s[1] == H}
    assert hidden, "oracle: two-pass must materialize (M, H)"
    assert not (fused_shapes & hidden), fused_shapes & hidden
    assert str(jaxpr_f).count("pallas_call") == 1


def test_expert_fn_fused_in_fmoe():
    """impl="fused" through the full MoE layer == the einsum expert_fn."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_expert_hidden=48)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    for act in ("swiglu", "gelu"):
        p = fmoe.fmoe_init(jax.random.PRNGKey(0), 32, cfg, act=act)
        y0, _ = fmoe.fmoe_apply(p, x, cfg, act=act, impl="einsum")
        y1, _ = fmoe.fmoe_apply(p, x, cfg, act=act, impl="fused")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-5,
                                   atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([2, 4]), bm=st.sampled_from([8, 16]),
       bh=st.sampled_from([8, 16, 64]), seed=st.integers(0, 100))
def test_fused_property(E, bm, bh, seed):
    rng = np.random.default_rng(seed)
    gs = jnp.asarray(rng.integers(0, 30, E), jnp.int32)
    M = max(int(gs.sum()), 1)
    gs = gs.at[0].add(M - int(gs.sum()))
    x = jnp.asarray(rng.normal(size=(M, 16)), jnp.float32)
    ws = (jnp.asarray(rng.normal(size=(E, 16, 24)) * 0.2, jnp.float32),
          jnp.asarray(rng.normal(size=(E, 16, 24)) * 0.2, jnp.float32))
    wo = jnp.asarray(rng.normal(size=(E, 24, 8)) * 0.2, jnp.float32)
    y = ops.fused_grouped_ffn(x, ws, wo, gs, "swiglu", bm, bh)
    y_ref = ops.ffn_two_pass(x, ws, wo, gs, "swiglu", "pallas", bm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4,
                               atol=1e-4)
