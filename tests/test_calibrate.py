"""Cost-model calibration from measured benchmark results (ROADMAP
follow-on: constants from benchmarks/results/results.json, not v5e)."""
import json
import os

import numpy as np

from repro.launch.roofline import ICI_BW, PEAK_FLOPS
from repro.placement import (CostConstants, calibrate_constants,
                             identity_placement, load_calibration,
                             placement_cost, plan_placement)
from repro.placement.calibrate import default_results_path


def test_informative_fig8_sets_wire_bandwidth():
    res = {"fig8": [{"us_off": 1000.0, "us_on": 600.0,
                     "a2a_elems_off": 262144, "a2a_elems_on": 98304,
                     "backend": "tpu"}]}
    c = calibrate_constants(res)
    expect = 2.0 * (262144 - 98304) * 4 / 400e-6
    np.testing.assert_allclose(c.ici_bw, expect, rtol=1e-9)
    assert c.source == "measured:fig8"


def test_non_informative_measurements_keep_roofline():
    # us_on > us_off: shrinking the buffer didn't pay on this machine
    res = {"fig8": [{"us_off": 600.0, "us_on": 1000.0,
                     "a2a_elems_off": 262144, "a2a_elems_on": 98304,
                     "backend": "tpu"}]}
    c = calibrate_constants(res)
    assert c.ici_bw == ICI_BW and c.source == "v5e-roofline"
    # absurd deltas are clamped out too
    res = {"fig8": [{"us_off": 1e12, "us_on": 0.0,
                     "a2a_elems_off": 2, "a2a_elems_on": 1,
                     "backend": "tpu"}]}
    assert calibrate_constants(res).ici_bw == ICI_BW


def test_cpu_fake_device_rows_never_calibrate():
    """Fake-device 'collectives' are memcpys: a CPU-tagged (or untagged,
    pre-tag) fig8 row with a right-sign delta must NOT set the wire
    bandwidth — it would price real ICI traffic ~100x too expensive."""
    row = {"us_off": 24357.5, "us_on": 21946.2,
           "a2a_elems_off": 262144, "a2a_elems_on": 98304}
    for tag in ({"backend": "cpu"}, {}):
        c = calibrate_constants({"fig8": [dict(row, **tag)],
                                 "fig3": [dict(gflops=50.0, **tag)]})
        assert c == CostConstants(), tag


def test_fig3_sets_peak_flops():
    res = {"fig3": [{"gflops": 55.0, "backend": "gpu"},
                    {"gflops": 112.5, "backend": "gpu"}]}
    c = calibrate_constants(res)
    assert c.peak_flops == 112.5e9 and "fig3" in c.source
    assert c.ici_bw == ICI_BW  # untouched without fig8


def test_load_calibration_handles_missing_and_real_file(tmp_path):
    assert load_calibration(str(tmp_path / "nope.json")) == CostConstants()
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_calibration(str(bad)) == CostConstants()
    good = tmp_path / "results.json"
    good.write_text(json.dumps({"fig3": [{"gflops": 7.0, "backend": "tpu"}]}))
    assert load_calibration(str(good)).peak_flops == 7e9
    # whatever is on disk must parse without blowing up; rows measured on a
    # CPU (fake-device) box must never calibrate — real-accelerator rows may
    c = load_calibration(default_results_path())
    assert c.ici_bw > 0 and c.peak_flops > 0
    path = default_results_path()
    if os.path.exists(path):
        rows = [r for rs in json.load(open(path)).values() for r in rs]
        if not any(r.get("backend") in ("tpu", "gpu") for r in rows):
            assert c.source == "v5e-roofline"


def test_constants_steer_the_planner():
    """The constants must actually change planning decisions: with HBM
    priced absurdly slow, streaming replicated shadow weights never pays."""
    p = 1.0 / (np.arange(16) + 1) ** 1.2
    load = p / p.sum()
    kw = dict(d_model=64, d_hidden=128, capacity=256, capacity_factor=2.0)
    assert plan_placement(load, 4, **kw).num_shadow > 0
    slow_hbm = CostConstants(hbm_bw=1e3)
    assert plan_placement(load, 4, constants=slow_hbm, **kw).num_shadow == 0
    # and the cost report prices with them
    place = identity_placement(16, 4)
    base = placement_cost(place, load, **kw)
    scaled = placement_cost(place, load,
                            constants=CostConstants(ici_bw=ICI_BW / 10), **kw)
    np.testing.assert_allclose(scaled.a2a_s, 10 * base.a2a_s, rtol=1e-9)
    assert base.total_s < scaled.total_s
    _ = PEAK_FLOPS  # referenced: flop term intentionally cancels in the model
