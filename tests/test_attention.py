"""Blockwise (flash-pattern) attention vs a naive softmax oracle —
shape/window/chunk sweeps + hypothesis properties (guards the online-softmax
rescaling, KV padding, and sliding-window masking)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.models.attention as A


def naive_attention(q, k, v, *, window, causal=True, q_offset=0):
    B, Sq, H, dk = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dk).astype(jnp.float32)
    s = jnp.einsum("bskgd,bckd->bskgc", qg, k.astype(jnp.float32)) * dk ** -0.5
    i = q_offset + jnp.arange(Sq)[:, None]
    j = jnp.arange(Skv)[None, :]
    mask = (i - j) < window
    if causal:
        mask &= (i - j) >= 0
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgc,bckd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, -1).astype(q.dtype)


def _qkv(B, S, H, KV, dk, seed=0, Skv=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Skv = Skv or S
    return (jax.random.normal(ks[0], (B, S, H, dk)),
            jax.random.normal(ks[1], (B, Skv, KV, dk)),
            jax.random.normal(ks[2], (B, Skv, KV, dk)))


@pytest.mark.parametrize("chunk", [4, 8, 32])
@pytest.mark.parametrize("S", [16, 30])  # 30: not a chunk multiple -> padding
def test_blockwise_matches_naive(S, chunk):
    q, k, v = _qkv(2, S, 8, 4, 16)
    y = A.blockwise_attention(q, k, v, window=1 << 30, chunk=chunk)
    y_ref = naive_attention(q, k, v, window=1 << 30)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


@pytest.mark.parametrize("window", [1, 3, 8, 1 << 30])
def test_sliding_window(window):
    q, k, v = _qkv(1, 24, 4, 4, 8, seed=1)
    y = A.blockwise_attention(q, k, v, window=window, chunk=8)
    y_ref = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_non_causal_cross_attention_with_padding():
    # whisper cross-attn: Skv=30 frames, chunk 16 -> padded tail masked
    q, _, _ = _qkv(2, 6, 4, 4, 8, seed=2)
    _, k, v = _qkv(2, 6, 4, 4, 8, seed=3, Skv=30)
    y = A.blockwise_attention(q, k, v, window=1 << 30, chunk=16, causal=False)
    y_ref = naive_attention(q, k, v, window=1 << 30, causal=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(S=st.integers(2, 40), KV=st.sampled_from([1, 2, 4]),
       G=st.sampled_from([1, 2, 3]), chunk=st.sampled_from([4, 8, 16]),
       window=st.integers(1, 50), seed=st.integers(0, 50))
def test_blockwise_property(S, KV, G, chunk, window, seed):
    q, k, v = _qkv(1, S, KV * G, KV, 8, seed=seed)
    y = A.blockwise_attention(q, k, v, window=window, chunk=chunk)
    y_ref = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-5)


def test_bf16_score_mode_close_to_f32():
    q, k, v = _qkv(2, 32, 8, 4, 16, seed=4)
    q, k, v = (t.astype(jnp.bfloat16) for t in (q, k, v))
    y32 = A.blockwise_attention(q, k, v, window=1 << 30, chunk=8)
    with A.score_dtype(jnp.bfloat16):
        y16 = A.blockwise_attention(q, k, v, window=1 << 30, chunk=8)
    np.testing.assert_allclose(np.asarray(y16, np.float32),
                               np.asarray(y32, np.float32), atol=3e-2)


def test_decode_attention_matches_naive_last_row():
    B, S, H, KV, dk = 2, 12, 4, 2, 8
    q, k, v = _qkv(B, S, H, KV, dk, seed=5)
    full = naive_attention(q, k, v, window=1 << 30)
    cache = A.KVCache(k, v, jnp.broadcast_to(jnp.arange(S), (B, S)))
    out = A.decode_attention(q[:, -1:], cache.k, cache.v, cache.positions,
                             jnp.int32(S - 1), 1 << 30)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)
