"""Every example script must run end-to-end (subprocess smoke)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "ok" in out and "max |fast - naive|" in out


def test_fmoefy_example():
    out = _run("fmoefy_transformer.py")
    assert "granite-3-2b-moe96" in out


def test_expert_parallel_example():
    out = _run("expert_parallel.py")
    assert "all-to-all ops in compiled HLO: 3" in out


def test_train_example_short():
    out = _run("train_moe_lm.py", "--steps", "6", "--layers", "2",
               "--d_model", "64", "--batch", "4", "--seq", "32")
    assert "loss" in out


def test_serve_example():
    out = _run("serve_decode.py", "--batch", "2", "--gen", "4",
               "--prompt_len", "4")
    assert "tok/s" in out
