"""Fault-tolerance tests (ISSUE 8 acceptance).

Unit layer: atomic verified checkpoints (checksums, the ``complete``
marker, numeric step ordering, strict dtypes, GC), the step guard
(non-finite skip/restore/abort, drop-spike fallback), replan probation,
and the deterministic fault registry.

Drill layer (subprocess, via tests/dist_utils.py): the CLI drills the
issue names — SIGKILL mid-save then ``--resume`` restores the last
complete checkpoint; an injected NaN step is skipped and retried from the
last good state; resume reproduces the uninterrupted run bitwise; a
post-replan loss regression rolls the migration back and blacklists the
plan — each leaving its obs event trail.
"""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dist_utils as du
from repro.checkpoint import ckpt
from repro.obs import events as obs_events
from repro.resilience import (CheckpointManager, ReplanProbation, StepGuard,
                              TrainingAborted, faults)


class ListSink:
    def __init__(self):
        self.records = []

    def emit(self, rec):
        self.records.append(rec)

    def kinds(self):
        return [r.get("kind") for r in self.records]


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    faults.set_sink(None)
    yield
    faults.clear()
    faults.set_sink(None)


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "inner": {"b": jnp.ones((5,), jnp.bfloat16),
                      "step": jnp.int32(7)}}


# ---------------------------------------------------------------------------
# Checkpoint durability units
# ---------------------------------------------------------------------------


def test_save_restore_roundtrip_bitwise(tmp_path):
    tree = _tree()
    path = str(tmp_path / "step_00000003")
    ckpt.save(path, tree, step=3)
    out = ckpt.restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    m = ckpt.load_manifest(path)
    assert m["complete"] and m["step"] == 3
    # bf16 leaves declare bf16 in the manifest even though the file is f32
    assert m["params"]["inner/b"]["dtype"] == "bfloat16"


def test_incomplete_and_tmp_dirs_are_invisible(tmp_path):
    """Satellite 1: latest_step skips torn writes and temp dirs, and sorts
    steps numerically (step_9 < step_10000 — the lexicographic trap)."""
    root = str(tmp_path)
    tree = _tree()
    for s in (9, 10000):
        ckpt.save(ckpt.step_path(root, s), tree, step=s)
    # a torn legacy write: arrays but no manifest
    torn = ckpt.step_path(root, 20000)
    os.makedirs(torn)
    np.save(os.path.join(torn, "arr_00000.npy"), np.zeros(3))
    # an interrupted save: manifest present but no complete marker
    unmarked = ckpt.step_path(root, 30000)
    shutil.copytree(ckpt.step_path(root, 9), unmarked)
    m = ckpt.load_manifest(unmarked)
    del m["complete"]
    with open(os.path.join(unmarked, ckpt.MANIFEST), "w") as f:
        json.dump(m, f)
    # a crashed save's temp dir
    os.makedirs(os.path.join(root, ".tmp-step_99999999.12345"))
    assert ckpt.latest_step(root) == ckpt.step_path(root, 10000)
    assert [s for s, _ in ckpt.complete_steps(root)] == [9, 10000]
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(unmarked, tree)


def test_crash_mid_save_leaves_prior_checkpoint_intact(tmp_path):
    """In-process analogue of the SIGKILL drill: a save that dies before
    the atomic publish leaves only the temp dir; the prior checkpoint and
    latest_step are untouched."""
    root = str(tmp_path)
    tree = _tree()
    ckpt.save(ckpt.step_path(root, 1), tree, step=1)

    class Boom(Exception):
        pass

    real_replace = os.replace

    def no_publish(src, dst):
        raise Boom  # everything before the publish already happened

    os.replace = no_publish
    try:
        with pytest.raises(Boom):
            ckpt.save(ckpt.step_path(root, 2), tree, step=2)
    finally:
        os.replace = real_replace
    assert ckpt.latest_step(root) == ckpt.step_path(root, 1)
    assert any(d.startswith(".tmp-") for d in os.listdir(root))
    # GC (from another pid's perspective) sweeps the stale temp dir
    stale = [d for d in os.listdir(root) if d.startswith(".tmp-")][0]
    os.rename(os.path.join(root, stale),
              os.path.join(root, ".tmp-step_00000002.99999"))
    removed = ckpt.gc_checkpoints(root, keep=3)
    assert len(removed) == 1
    assert not any(d.startswith(".tmp-") for d in os.listdir(root))


def test_restore_catches_bit_rot(tmp_path):
    tree = _tree()
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, tree, step=1)
    victim = os.path.join(path, ckpt.load_manifest(path)["params"]["w"]["file"])
    faults.corrupt_file(victim)
    with pytest.raises(ckpt.CheckpointError, match="checksum"):
        ckpt.restore(path, tree)
    ckpt.restore(path, tree, verify=False)  # opt-out still loads


def test_restore_dtype_strict(tmp_path):
    """Satellite 2: manifest dtype must match the restore target; the only
    coercion is the internal bf16<->f32 storage round-trip."""
    tree = {"w": jnp.ones((2, 2), jnp.float32)}
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, tree, step=1)
    with pytest.raises(ValueError, match="dtype"):
        ckpt.restore(path, {"w": jnp.ones((2, 2), jnp.bfloat16)})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(path, {"w": jnp.ones((2, 3), jnp.float32)})
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(path, {"v": jnp.ones((2, 2), jnp.float32)})


def test_corrupt_array_fault_is_caught_by_restore(tmp_path):
    """The registry's post-checksum corrupt_array fault models bit-rot the
    manifest checksum must catch (match filters by flat key)."""
    faults.arm({"kind": "corrupt_array", "point": "ckpt_save_file",
                "match": "inner/b", "at": 1})
    tree = _tree()
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, tree, step=1)
    assert faults.fired and faults.fired[0]["fault_kind"] == "corrupt_array"
    with pytest.raises(ckpt.CheckpointError, match="inner/b"):
        ckpt.restore(path, tree)


def test_manager_cadence_gc_and_corrupt_fallback(tmp_path):
    sink = ListSink()
    mgr = CheckpointManager(str(tmp_path), save_every=2, keep=2, sink=sink)
    tree = _tree()
    for s in range(6):
        mgr.maybe_save(s, tree)
    # cadence counts completed steps: saves after 1, 3, 5; keep=2 GCs step 1
    assert [s for s, _ in ckpt.complete_steps(str(tmp_path))] == [3, 5]
    assert obs_events.of_kind(sink.records, obs_events.CKPT_GC)
    # corrupt the newest: restore_latest falls back to step 3 with events
    newest = ckpt.step_path(str(tmp_path), 5)
    faults.corrupt_file(os.path.join(
        newest, ckpt.load_manifest(newest)["params"]["w"]["file"]))
    out = mgr.restore_latest(tree)
    assert out is not None and out[1] == 3
    assert [r["step"] for r in
            obs_events.of_kind(sink.records, obs_events.CKPT_CORRUPT)] == [5]
    assert [r["step"] for r in
            obs_events.of_kind(sink.records, obs_events.RESUME)] == [3]


# ---------------------------------------------------------------------------
# Step guard units
# ---------------------------------------------------------------------------


def test_guard_skip_restore_then_abort():
    sink = ListSink()
    g = StepGuard(max_bad_steps=2, sink=sink)
    p, o = {"w": jnp.ones((3,))}, {"m": jnp.zeros((3,))}
    g.commit(0, p, o)
    assert not g.check(1, loss=float("nan")).ok
    rp, ro = g.restore()
    np.testing.assert_array_equal(np.asarray(rp["w"]), np.ones(3))
    assert rp["w"] is not p["w"]  # fresh copy: safe to donate
    assert not g.check(1, loss=1.0, grad_norm=float("inf")).ok
    with pytest.raises(TrainingAborted):
        g.check(1, loss=float("nan"))
    ks = sink.kinds()
    assert ks.count(obs_events.GUARD_SKIP) == 3
    assert ks[-1] == obs_events.GUARD_ABORT
    # a good step resets the streak
    g2 = StepGuard(max_bad_steps=1)
    g2.commit(0, p, o)
    for s in range(1, 5):  # alternating bad/good never aborts
        assert not g2.check(s, loss=float("nan")).ok
        g2.commit(s, p, o)
        assert g2.check(s, loss=0.5).ok


def test_guard_snapshot_cadence_and_force():
    g = StepGuard(snapshot_every=4)
    p = {"w": jnp.zeros((2,))}
    g.commit(0, p, p)
    g.commit(1, {"w": jnp.ones((2,))}, p)  # within cadence: not snapshotted
    assert g.snapshot_step == 0
    g.commit(2, {"w": jnp.full((2,), 2.0)}, p, force=True)  # post-migration
    assert g.snapshot_step == 2
    np.testing.assert_array_equal(np.asarray(g.restore()[0]["w"]),
                                  np.full(2, 2.0))


def test_guard_drop_fallback_is_one_shot():
    sink = ListSink()
    g = StepGuard(drop_threshold=0.2, drop_patience=3, sink=sink)
    g.commit(0, {}, {})
    hits = [g.check(s, loss=1.0, drop=0.5).fallback_dropless
            for s in range(1, 10)]
    assert hits == [False, False, True] + [False] * 6
    assert sink.kinds().count(obs_events.DROP_SPIKE) == 1
    # sub-threshold steps reset the streak
    g2 = StepGuard(drop_threshold=0.2, drop_patience=3)
    g2.commit(0, {}, {})
    seq = [0.5, 0.5, 0.1, 0.5, 0.5, 0.5]
    assert [g2.check(i, loss=1.0, drop=d).fallback_dropless
            for i, d in enumerate(seq)] == [False] * 5 + [True]


# ---------------------------------------------------------------------------
# Probation + fault registry units
# ---------------------------------------------------------------------------


def test_probation_rollback_and_commit():
    sink = ListSink()
    pr = ReplanProbation(window=8, loss_tol=1.05, min_samples=3, sink=sink)
    pr.start(10, "OLD", "NEW", baseline_loss=1.0, baseline_drop=0.0)
    assert not pr.observe(11, loss=2.0).rollback  # min_samples not reached
    assert not pr.observe(12, loss=2.0).rollback
    d = pr.observe(13, loss=2.0)
    assert d.rollback and d.old_plan == "OLD" and d.new_plan == "NEW"
    assert not pr.active
    assert sink.kinds() == [obs_events.REPLAN_ROLLBACK]
    # surviving the window commits
    pr.start(20, "OLD", "NEW2", baseline_loss=1.0, baseline_drop=0.0)
    for s in range(21, 29):
        assert not pr.observe(s, loss=1.0).rollback
    assert not pr.active
    assert sink.kinds()[-1] == obs_events.REPLAN_COMMIT
    # drop regression judges even without a loss baseline
    pr.start(30, "OLD", "NEW3", baseline_drop=0.0)
    for _ in range(3):
        d = pr.observe(31, drop=0.2)
    assert d.rollback


def test_fault_hit_count_and_nonfinite_one_shot():
    faults.arm({"kind": "nonfinite", "point": "train_step", "step": 3,
                "until": 100})
    p = {"w": jnp.ones((2,), jnp.bfloat16), "i": jnp.int32(1)}
    m = {"loss": jnp.float32(1.0), "grad_norm": jnp.float32(1.0)}
    p1, _, m1 = faults.apply_step(p, {}, m, step=2)
    assert np.isfinite(float(m1["loss"]))  # before the step range
    p2, _, m2 = faults.apply_step(p, {}, m, step=3)
    assert not np.isfinite(float(m2["loss"]))
    assert not np.isfinite(np.asarray(p2["w"], np.float32)).any()
    assert p2["w"].dtype == jnp.bfloat16 and int(p2["i"]) == 1
    # one-shot even though the step range extends: the retry must succeed
    p3, _, m3 = faults.apply_step(p, {}, m, step=3)
    assert np.isfinite(float(m3["loss"]))
    assert not faults.armed()
    # drop_spike overrides metrics only
    faults.arm({"kind": "drop_spike", "point": "train_step", "step": 5,
                "value": 0.9})
    _, _, m4 = faults.apply_step(p, {}, m, step=5)
    assert float(m4["drop_frac"]) == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# CLI drills (subprocess; the acceptance scenarios the issue names)
# ---------------------------------------------------------------------------


_CLI = ["repro.launch.train", "--arch", "fastmoe-gpt", "--reduced",
        "--batch", "2", "--seq", "32", "--log_every", "1"]


def _losses(out: str) -> dict:
    """step -> printed loss (4 decimals: the bitwise-equality fingerprint)."""
    res = {}
    for line in out.splitlines():
        parts = line.split()
        if len(parts) >= 4 and parts[0] == "step" and parts[2] == "loss":
            res[int(parts[1])] = parts[3]
    return res


@pytest.fixture(scope="module")
def reference_run():
    """One uninterrupted 6-step run; every drill must reproduce its losses."""
    return _losses(du.run_cli(_CLI + ["--steps", "6"], devices=1))


def test_cli_crash_mid_save_then_resume(tmp_path, reference_run):
    """SIGKILL (os._exit) right before the atomic publish of the step-3
    checkpoint: the partial save is invisible, --resume restores step 1 and
    replays to the reference trajectory bitwise."""
    ck = str(tmp_path / "ck")
    spec = [{"kind": "crash", "point": "ckpt_save_pre_commit", "at": 2}]
    out = du.run_cli(_CLI + ["--steps", "6", "--ckpt_dir", ck,
                             "--save_every", "2"],
                     devices=1, env={"REPRO_FAULTS": json.dumps(spec)},
                     check=False)
    assert out.returncode == faults.CRASH_EXIT_CODE, out.stderr[-2000:]
    assert ckpt.latest_step(ck) == ckpt.step_path(ck, 1)
    assert any(d.startswith(".tmp-") for d in os.listdir(ck))
    metrics = str(tmp_path / "m.jsonl")
    out2 = du.run_cli(_CLI + ["--steps", "6", "--ckpt_dir", ck,
                              "--save_every", "2", "--resume",
                              "--metrics_out", metrics], devices=1)
    assert "resumed from step 1" in out2
    got = _losses(out2)
    assert all(got[s] == reference_run[s] for s in range(2, 6)), (
        got, reference_run)
    kinds = [json.loads(l).get("kind") for l in open(metrics)]
    assert obs_events.RESUME in kinds and obs_events.CKPT_SAVE in kinds
    assert not any(d.startswith(".tmp-") for d in os.listdir(ck))  # GC swept


def test_cli_nan_step_skipped_and_retried(tmp_path, reference_run):
    """An injected NaN at step 2 is skipped; the retry from the last good
    snapshot lands on the uninterrupted trajectory, with the incident trail
    (fault -> guard_skip -> guard_restore) in --metrics_out."""
    metrics = str(tmp_path / "m.jsonl")
    spec = [{"kind": "nonfinite", "point": "train_step", "step": 2}]
    out = du.run_cli(_CLI + ["--steps", "4", "--metrics_out", metrics],
                     devices=1, env={"REPRO_FAULTS": json.dumps(spec)})
    assert "non-finite" in out and "retrying" in out
    got = _losses(out)
    assert all(got[s] == reference_run[s] for s in range(4)), (
        got, reference_run)
    kinds = [json.loads(l).get("kind") for l in open(metrics)]
    i = kinds.index(obs_events.FAULT)
    assert kinds[i:i + 3] == [obs_events.FAULT, obs_events.GUARD_SKIP,
                              obs_events.GUARD_RESTORE]


def test_cli_resume_equivalence(tmp_path, reference_run):
    """Stop at 4, resume to 6: the resumed half matches the uninterrupted
    run bitwise (the data stream fast-forwards deterministically)."""
    ck = str(tmp_path / "ck")
    du.run_cli(_CLI + ["--steps", "4", "--ckpt_dir", ck], devices=1)
    out = du.run_cli(_CLI + ["--steps", "6", "--ckpt_dir", ck, "--resume"],
                     devices=1)
    assert "resumed from step 3" in out
    got = _losses(out)
    assert all(got[s] == reference_run[s] for s in (4, 5)), (
        got, reference_run)


def test_cli_sustained_drop_spike_emits_fallback(tmp_path):
    """A sustained injected drop spike trips the guard's one-shot dropless
    fallback (event-only off-mesh; the re-jit needs a bounded exchange)."""
    metrics = str(tmp_path / "m.jsonl")
    spec = [{"kind": "drop_spike", "point": "train_step", "step": 0,
             "until": 6, "value": 0.9}]
    out = du.run_cli(_CLI + ["--steps", "6", "--metrics_out", metrics,
                             "--drop_patience", "3"],
                     devices=1, env={"REPRO_FAULTS": json.dumps(spec)})
    assert "sustained drop spike" in out
    kinds = [json.loads(l).get("kind") for l in open(metrics)]
    assert kinds.count(obs_events.DROP_SPIKE) == 1
    assert kinds.count(obs_events.DROP_FALLBACK) == 1


def test_replan_rollback_drill():
    """Hook-level acceptance: a replan whose post-migration loss regresses
    is inverted — params round-trip bitwise, the plan is blacklisted, and
    the controller never proposes it again."""
    print(du.run("""
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import ReplanHook, jit_train_step
    from repro.models import lm
    from repro.optim import AdamW

    class Sink:
        def __init__(self): self.records = []
        def emit(self, rec): self.records.append(rec)

    cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                           num_experts=16))
    mesh = make_local_mesh(1, 4)
    opt = AdamW()
    B, S = 8, 32
    sink = Sink()
    hook = ReplanHook(cfg, opt, mesh, B, S, every=2, sink=sink)
    hook.controller.min_gain = -10.0  # force accept to exercise rollback
    _, pshard, oshard = jit_train_step(cfg, opt, mesh, B, S)
    params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg),
                            pshard)
    opt_state = jax.device_put(opt.init(params), oshard)
    p0 = jax.tree.map(np.asarray, jax.device_get(params))
    skew = {"load": 1.0 / (np.arange(16) + 1) ** 1.5, "drop_frac": 0.0}
    step, new_fn = 0, None
    while new_fn is None:  # healthy baseline until the replan fires
        params, opt_state, new_fn = hook.observe(step, skew, params,
                                                 opt_state, loss=1.0)
        step += 1
    bad_plan = hook.placement
    assert hook.probation.active
    rolled = False
    for _ in range(10):  # regressing stream: probation must invert it
        params, opt_state, fn = hook.observe(step, skew, params, opt_state,
                                             loss=5.0)
        step += 1
        if hook.controller.rollbacks:
            rolled = fn is not None
            break
    assert rolled, "rollback never fired"
    assert bad_plan in hook.controller._blacklist
    p1 = jax.tree.map(np.asarray, jax.device_get(params))
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(a, b)  # migration inverted bitwise
    kinds = [r.get("kind") for r in sink.records]
    assert "replan_rollback" in kinds
    for _ in range(6):  # blacklisted: the same skew re-proposes nothing
        params, opt_state, fn = hook.observe(step, skew, params, opt_state,
                                             loss=1.0)
        assert fn is None, "blacklisted plan re-proposed"
        step += 1
    print("rollback drill ok")
    """, devices=4))
