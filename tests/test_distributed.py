"""Multi-(fake-)device execution tests, run in subprocesses so the main test
process keeps its single CPU device (per the dry-run contract).

All multi-rank emulation plumbing lives in tests/dist_utils.py (the
consolidated differential harness); scripts import it inside the subprocess.
The headline test is the dispatch × impl × dist × overlap matrix sweep:
every combination must reproduce the single-rank oracle.
"""
import pytest

import dist_utils as du


# ---------------------------------------------------------------------------
# The matrix: dispatch × impl × dist-mode × overlap vs the single-rank oracle
# ---------------------------------------------------------------------------

# one subprocess per (dispatch, dist-mode) cell; impl × overlap loop inside
# (jax import dominates subprocess cost, not the tiny jitted layers)
@pytest.mark.parametrize("dispatch,dist_mode", [
    ("capacity", "a2a"), ("capacity", "psum"),
    ("ragged", "a2a"), ("ragged", "psum"),
])
def test_matrix_matches_single_rank_oracle(dispatch, dist_mode):
    out = du.run(f"""
    import numpy as np, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    dispatch, dist_mode = {dispatch!r}, {dist_mode!r}
    env = du.moe_env(dispatch=dispatch)
    mesh = du.make_mesh()
    axes = ("data", "model") if dist_mode == "a2a" else ("data",)
    for impl in ("einsum", "pallas", "fused"):
        y_ref, m_ref = du.oracle(env, impl=impl)
        for nc in (0, 2):
            dist = fmoe.DistConfig(mesh, axes, overlap_chunks=nc)
            assert dist.mode == dist_mode
            y, m = du.dist_apply(env, mesh, dist, impl=impl)
            du.assert_close(y, y_ref, 1e-5, msg=(impl, nc))
            np.testing.assert_allclose(np.asarray(m.load),
                                       np.asarray(m_ref.load), atol=1e-6)
            if dispatch == "ragged":
                assert float(m.drop_frac) == 0.0  # dropless by construction
    print("matrix cell ok")
    """)
    assert "matrix cell ok" in out


# the router axis of the same matrix (ISSUE 10 hard bar: new routers slot
# into the existing sweep — same oracle, same assertions, no parallel
# plumbing).  One subprocess per router; dispatch × dist × overlap inside.
# "topk" is the baseline above; expert-choice routes per token shard, so its
# oracle is the shard-wise local apply over the dist's token axes.
@pytest.mark.parametrize("router", ["noisy_topk", "gumbel", "expert_choice",
                                    "frozen"])
def test_router_matrix_matches_single_rank_oracle(router):
    out = du.run(f"""
    import numpy as np, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    router = {router!r}
    mesh = du.make_mesh()
    for dispatch in ("capacity", "ragged"):
        env = du.moe_env(dispatch=dispatch, router=router)
        for axes in (("data", "model"), ("data",)):
            for nc in ((0, 2) if axes == ("data", "model") else (0,)):
                dist = fmoe.DistConfig(mesh, axes, overlap_chunks=nc)
                if router == "expert_choice":
                    n_tok = 1
                    for a in dist.token_axes:
                        n_tok *= mesh.shape[a]
                    y_ref, load_ref = du.oracle_sharded(env, n_tok)
                else:
                    y_ref, m_ref = du.oracle(env)
                    load_ref = m_ref.load
                y, m = du.dist_apply(env, mesh, dist)
                du.assert_close(y, y_ref, 1e-5, msg=(dispatch, axes, nc))
                np.testing.assert_allclose(np.asarray(m.load),
                                           np.asarray(load_ref), atol=1e-6)
                if router == "expert_choice":
                    # flat by construction, and dropless at any shard count
                    np.testing.assert_allclose(
                        np.asarray(m.load), 1.0 / env.cfg.num_experts,
                        atol=1e-6)
                    assert float(m.drop_frac) == 0.0
                if dispatch == "ragged":
                    assert float(m.drop_frac) == 0.0
    print("router cell ok")
    """)
    assert "router cell ok" in out


def test_a2a_and_psum_match_naive_baseline():
    """The paper-faithful oracle: the Rau-style masked loop."""
    print(du.run("""
        import jax.numpy as jnp
        import dist_utils as du
        from repro.core import fmoe, naive
        env = du.moe_env()
        mesh = du.make_mesh()
        y_ref = naive.moe_loop_masked(env.params, env.x, env.cfg)
        for axes in [("data", "model"), ("data",)]:
            y, m = du.dist_apply(env, mesh, fmoe.DistConfig(mesh, axes))
            du.assert_close(y, y_ref, 1e-5, msg=axes)
            print("mode", fmoe.DistConfig(mesh, axes).mode, "ok")
    """))


def test_a2a_collective_appears_in_hlo():
    out = du.run("""
        import jax
        import dist_utils as du
        from repro.core import fmoe
        env = du.moe_env()
        mesh = du.make_mesh()
        dist = fmoe.DistConfig(mesh, ("data", "model"))
        with mesh:
            lowered = jax.jit(lambda p, x: fmoe.fmoe_apply(
                p, x, env.cfg, dist=dist)[0]).lower(env.params, env.x)
        txt = lowered.compile().as_text()
        assert "all-to-all" in txt, "expected all-to-all in HLO"
        print("all-to-all present")
    """)
    assert "all-to-all present" in out


def test_gradient_sync_semantics():
    """Paper §3.2: replicated (world) param grads identical across all
    devices; expert (none-tag) grads live only on their shard."""
    print(du.run("""
        import jax, numpy as np
        import dist_utils as du
        from repro.core import fmoe
        from jax.sharding import NamedSharding, PartitionSpec as P
        env = du.moe_env()
        mesh = du.make_mesh()
        espec = jax.tree.map(lambda _: NamedSharding(mesh, P("model", None, None)),
                             env.params["experts"])
        rspec = jax.tree.map(lambda _: NamedSharding(mesh, P(None, None)),
                             env.params["router"])
        params = {"router": jax.device_put(env.params["router"], rspec),
                  "experts": jax.device_put(env.params["experts"], espec)}
        dist = fmoe.DistConfig(mesh, ("data", "model"))
        g = du.layer_grads(env, dist, mesh=mesh, params=params)
        # router grad: replicated => every device shard identical (world tag)
        rshards = [np.asarray(s.data) for s in g["router"]["w"].addressable_shards]
        for s in rshards[1:]:
            np.testing.assert_allclose(s, rshards[0], atol=1e-6)
        # expert grad: sharded over model on dim 0 (none tag)
        sh = g["experts"]["wi_gate"].sharding
        assert "model" in (sh.spec[0] if isinstance(sh.spec[0], tuple) else (sh.spec[0],))
        print("sync tags verified")
    """))


def test_train_step_runs_on_mesh():
    print(du.run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_local_mesh
        from repro.launch.train import jit_train_step
        from repro.models import lm
        from repro.optim import AdamW
        cfg = reduced(get_config("arctic-480b"))
        mesh = make_local_mesh(2, 4)
        opt = AdamW()
        step, pshard, oshard = jit_train_step(cfg, opt, mesh, global_batch=8,
                                              seq_len=16)
        params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg), pshard)
        opt_state = jax.device_put(opt.init(params), oshard)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                              cfg.vocab_size)}
        with mesh:
            params, opt_state, m = step(params, opt_state, batch, jnp.int32(0))
        loss = float(m["loss"])
        assert loss > 0 and loss < 20
        print("distributed train step ok, loss", loss)
    """))


def test_cache_seq_sharded_decode_matches_single_device():
    """Window-sharded KV cache (§Perf decode opt) must be numerically
    transparent: sharded decode == local decode."""
    print(du.run("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced
        from repro.launch.sharding import cache_specs
        from repro.models import lm
        cfg = reduced(get_config("qwen2-72b"))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        B, W = 8, 8192  # W >= model_axis*2048 so the seq-shard gate engages
        cache = lm.init_cache(cfg, B, W)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0, cfg.vocab_size)
        # local reference
        ref_cache, outs = cache, []
        for t in range(6):
            lg, ref_cache, _ = lm.decode_step(params, cfg, toks[:, t:t+1],
                                              jnp.int32(t), ref_cache)
            outs.append(lg)
        ref = jnp.concatenate(outs, 1)
        # sharded: batch over data, window over model
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        specs = cache_specs(jax.eval_shape(lambda: lm.init_cache(cfg, B, W)),
                            mesh, B, seq_shard=True)
        flat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        assert any("model" in str(s) for s in flat), specs  # gate engaged
        cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                              is_leaf=lambda s: isinstance(s, P))
        cache_s = jax.device_put(lm.init_cache(cfg, B, W), cshard)
        step = jax.jit(functools.partial(lm.decode_step, cfg=cfg))
        outs = []
        with mesh:
            for t in range(6):
                lg, cache_s, _ = step(params, tokens=toks[:, t:t+1],
                                      pos=jnp.int32(t), cache=cache_s)
                outs.append(lg)
        got = jnp.concatenate(outs, 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)
        print("cache-sharded decode ok")
    """))


def test_cross_pod_expert_parallelism_matches_local():
    """§Perf multi-pod: experts sharded over (pod, model) — the tuple-axis
    all-to-all must be numerically identical to the local layer."""
    print(du.run("""
        import jax, numpy as np
        import dist_utils as du
        from repro.core import fmoe
        env = du.moe_env(num_shared_experts=1)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        y_ref, _ = du.oracle(env)
        dist = fmoe.DistConfig(mesh, ("pod", "data", "model"),
                               expert_axis=("pod", "model"),
                               constrain_tokens=True)
        assert dist.mode == "a2a" and dist.expert_parallelism == 4
        y, m = du.dist_apply(env, mesh, dist)
        du.assert_close(y, y_ref, 1e-5)
        # grads flow through the cross-pod a2a
        g = du.layer_grads(env, dist, mesh=mesh)
        assert all(np.isfinite(np.asarray(l, np.float32)).all()
                   for l in jax.tree.leaves(g))
        print("cross-pod expert parallelism ok")
    """))


def test_hierarchical_a2a_equals_flat():
    """Beyond-paper 2-hop all-to-all must move the same data as 1-hop."""
    print(du.run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import shard_map
        from repro.core.comm import hierarchical_all_to_all
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        P = jax.sharding.PartitionSpec
        def flat(x):
            return jax.lax.all_to_all(x, ("pod", "data"), 0, 0, tiled=True)
        def hier(x):
            # (outer=pod, inner=data) layout: dim0 dest-pod, dim1 dest-data
            y = x.reshape(2, 4, -1)
            y = hierarchical_all_to_all(y, "data", "pod")
            return y.reshape(8, -1)
        # global (64, 16): local (8, 16) per device = one chunk per peer
        x = jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)
        f1 = shard_map(flat, mesh=mesh, in_specs=P(("pod", "data"), None),
                       out_specs=P(("pod", "data"), None), check_vma=False)
        f2 = shard_map(hier, mesh=mesh, in_specs=P(("pod", "data"), None),
                       out_specs=P(("pod", "data"), None), check_vma=False)
        with mesh:
            y1, y2 = f1(x), f2(x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
        print("hierarchical a2a ok")
    """))
