"""Unified telemetry (repro.obs): device-side wire/drop/shadow counters on
the MoE metrics pytree, the host-side span tracer, and the pluggable
metrics sinks.

The wire counters' contract is strong: for every distributed schedule
(serial a2a, ppermute-decomposed, bf16 wire, ragged/dropless) the counter
must equal BOTH the hand-computed exchange size AND the optimized HLO's
collective output bytes (roofline.collective_bytes) — and turning the
counters off (DistConfig.obs=False) must leave the program's collectives
byte-for-byte unchanged, i.e. telemetry is free.
"""
import json
import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dist_utils as du
from repro.core.monitor import LoadMonitor
from repro.obs import sink as obs_sink
from repro.obs import trace as obs_trace
from repro.obs.counters import ObsCounters
from repro.obs.stats import StepStats, modeled_collective_bytes


# ---------------------------------------------------------------------------
# Counters: single-device semantics + pytree accumulation
# ---------------------------------------------------------------------------


def test_local_counters_single_device():
    """No dist: nothing crosses any wire; dropped = drop_frac * (T * k)."""
    env = du.moe_env(capacity_factor=0.5)  # force capacity overflow
    y, m = du.oracle(env)
    T = env.x.shape[0] * env.x.shape[1]
    assert float(m.obs.wire_elems) == 0.0
    assert float(m.obs.wire_bytes) == 0.0
    assert float(m.obs.shadow_hits) == 0.0
    assert float(m.obs.imbalance) == 1.0
    assert float(m.drop_frac) > 0.0
    np.testing.assert_allclose(float(m.obs.dropped),
                               float(m.drop_frac) * T * env.cfg.top_k,
                               rtol=1e-5)


def test_counters_accumulate_like_metrics():
    """ObsCounters is '+'-accumulable (the layer scan sums it)."""
    a = ObsCounters(*(jnp.float32(v) for v in (1, 2, 3, 4, 1.5, 0.25, 0.75)))
    b = ObsCounters(*(jnp.float32(v) for v in (10, 20, 30, 40, 0.5, 1, 19)))
    s = a + b
    assert [float(v) for v in s] == [11, 22, 33, 44, 2.0, 1.25, 19.75]
    z = ObsCounters.zero()
    assert [float(v) for v in (z + a)] == [float(v) for v in a]
    d = a.as_dict()
    assert set(d) == {"wire_elems", "wire_bytes", "dropped", "shadow_hits",
                      "imbalance", "wire_bytes_intra", "wire_bytes_inter"}


# ---------------------------------------------------------------------------
# Multi-rank wire counters: hand math == device counter == optimized HLO
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_wire_counters_match_hand_math_and_hlo():
    out = du.run("""
    import numpy as np, jax, jax.numpy as jnp
    import dist_utils as du
    from repro.core import fmoe
    from repro.core.dispatch import expert_capacity
    from repro.launch.roofline import collective_bytes

    E, k, d = 8, 2, 32
    mesh = du.make_mesh(2, 4)  # tokens over 8 ranks, experts over mp=4
    axes = ("data", "model")
    mp, shards = 4, 8
    env = du.moe_env()          # T=128, capacity_factor=8 (no drops)
    t = 128 // shards
    C = expert_capacity(t, E, k, env.cfg.capacity_factor)

    def run(env, dist, params=None):
        with mesh:
            fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, env.cfg,
                                                      dist=dist))
            p = env.params if params is None else params
            y, m = fn(p, env.x)
            # lower the FULL (y, m) program so the counts leg isn't DCE'd
            txt = fn.lower(p, env.x).compile().as_text()
        cb = collective_bytes(txt)
        return m, float(cb.get("all-to-all", 0)
                        + cb.get("collective-permute", 0))

    # serial capacity a2a, f32 wire: full (E, C, d) payload each way + the
    # int32 Fig-2 counts exchange
    m, hlo = run(env, fmoe.DistConfig(mesh, axes))
    elems = E * C * d * 2 + E
    assert float(m.obs.wire_elems) == elems, (float(m.obs.wire_elems), elems)
    assert float(m.obs.wire_bytes) == 4 * elems
    assert float(m.obs.wire_bytes) == hlo, (float(m.obs.wire_bytes), hlo)
    assert float(m.obs.dropped) == 0.0
    assert float(m.obs.shadow_hits) == 0.0
    assert float(m.obs.imbalance) >= 1.0
    # flat (single-level) exchange: the split counters attribute every
    # byte to the inter-node share (tests/test_hier_a2a.py locks the
    # two-level split)
    assert float(m.obs.wire_bytes_intra) == 0.0
    assert float(m.obs.wire_bytes_inter) == float(m.obs.wire_bytes)

    # bf16 wire: payload bytes halve, counts leg stays int32
    m, hlo = run(env, fmoe.DistConfig(mesh, axes, wire_dtype="bf16"))
    b = E * C * d * 2 * 2 + E * 4
    assert float(m.obs.wire_bytes) == b, (float(m.obs.wire_bytes), b)
    assert float(m.obs.wire_bytes) == hlo, (float(m.obs.wire_bytes), hlo)

    # ppermute-decomposed pipeline: a rank's own slice never moves, so only
    # (mp-1)/mp of every leg (payloads AND counts) crosses the wire
    m, hlo = run(env, fmoe.DistConfig(mesh, axes, overlap_chunks=2))
    b = 0.75 * 4 * (E * C * d * 2 + E)
    assert float(m.obs.wire_bytes) == b, (float(m.obs.wire_bytes), b)
    assert float(m.obs.wire_bytes) == hlo, (float(m.obs.wire_bytes), hlo)

    # ragged (dropless): pad-to-max-per-peer shards, B = t*k rows per peer
    env_r = du.moe_env(dispatch="ragged")
    B = t * k
    m, hlo = run(env_r, fmoe.DistConfig(mesh, axes))
    elems = mp * B * d * 2 + E
    assert float(m.obs.wire_elems) == elems, (float(m.obs.wire_elems), elems)
    assert float(m.obs.wire_bytes) == 4 * elems
    assert float(m.obs.wire_bytes) == hlo, (float(m.obs.wire_bytes), hlo)
    assert float(m.obs.dropped) == 0.0

    # shadowed hot experts: skewed router sends every assignment to the two
    # shadowed experts -> shadow_hits counts ALL global (token, slot) pairs
    from repro.placement import from_logical
    envh = du.skew_router(du.moe_env())
    pl = du.hot_shadow_plan(np.array([10, 5, 3, 3, 2, 2, 1, 1], float), 4, 4)
    m, hlo = run(envh, fmoe.DistConfig(mesh, axes, placement=pl),
                 params=from_logical(envh.params, pl))
    assert float(m.obs.shadow_hits) == 128 * k, float(m.obs.shadow_hits)
    assert float(m.obs.dropped) == 0.0

    # psum (decode) mode: tokens sharded over data only -> one (t, d)
    # all-reduce is the entire wire traffic (no counts leg)
    m, _ = run(env, fmoe.DistConfig(mesh, ("data",)))
    t_ps = 128 // 2
    assert float(m.obs.wire_elems) == t_ps * d, float(m.obs.wire_elems)
    assert float(m.obs.wire_bytes) == t_ps * d * 4
    assert float(m.obs.imbalance) >= 1.0
    print("wire counters ok")
    """, devices=8)
    assert "wire counters ok" in out


@pytest.mark.tier1
def test_obs_off_leaves_collectives_byte_identical():
    """DistConfig.obs gates the counters; the HLO regression locking in
    'telemetry is free': obs=True vs obs=False programs have identical
    collective ops, byte for byte."""
    out = du.run("""
    import re
    import jax
    import dist_utils as du
    from repro.core import fmoe
    from repro.launch.roofline import collective_bytes

    # op DEFINITIONS only (result names recur as operand references, so a
    # raw substring count is meaningless); -start counted once, -done not
    OPRE = re.compile(r"=\\s*[^=]*?(all-reduce|all-gather|reduce-scatter"
                      r"|all-to-all|collective-permute)(-start)?\\(")

    def op_counts(txt):
        c = {}
        for m in OPRE.finditer(txt):
            c[m.group(1)] = c.get(m.group(1), 0) + 1
        return c

    mesh = du.make_mesh(1, 4)
    for dispatch, kw in (("capacity", {}), ("capacity",
                         dict(overlap_chunks=2, wire_dtype="bf16")),
                        ("ragged", {})):
        env = du.moe_env(dispatch=dispatch)
        txts = {}
        for obs in (True, False):
            dist = fmoe.DistConfig(mesh, ("data", "model"), obs=obs, **kw)
            with mesh:
                fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, env.cfg,
                                                          dist=dist))
                txts[obs] = fn.lower(env.params, env.x).compile().as_text()
        cb_on, cb_off = (collective_bytes(txts[o]) for o in (True, False))
        assert cb_on == cb_off, (dispatch, kw, cb_on, cb_off)
        assert op_counts(txts[True]) == op_counts(txts[False]), (
            dispatch, kw, op_counts(txts[True]), op_counts(txts[False]))
    print("obs off identical")
    """, devices=4)
    assert "obs off identical" in out


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_export_roundtrip(tmp_path):
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("outer", step=1) as s:
        assert isinstance(s, dict)
        with tr.span("inner"):
            pass
        s["tokens"] = 7  # body can attach results to the span args
    evs = tr.events
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert outer["args"]["depth"] == 0 and inner["args"]["depth"] == 1
    assert outer["args"]["tokens"] == 7 and outer["args"]["step"] == 1
    assert outer["dur"] >= inner["dur"] >= 0

    path = tr.export(str(tmp_path / "trace.json"))
    back = obs_trace.load_trace(path)
    assert back["traceEvents"] == evs
    assert all(e["ph"] == "X" for e in back["traceEvents"])


def test_tracer_disabled_is_noop_and_ring_bounded(tmp_path):
    tr = obs_trace.Tracer(enabled=False)
    with tr.span("x") as s:
        assert s is None
    assert tr.events == []

    tr = obs_trace.Tracer(enabled=True, max_events=3)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert [e["name"] for e in tr.events] == ["s7", "s8", "s9"]

    # module-level singleton: disabled by default, one configure() lights it
    assert not obs_trace.enabled()
    try:
        obs_trace.configure(enabled=True, max_events=16)
        with obs_trace.span("global"):
            pass
        assert [e["name"] for e in obs_trace.get().events] == ["global"]
    finally:
        obs_trace.configure(enabled=False)


# ---------------------------------------------------------------------------
# Metrics sinks
# ---------------------------------------------------------------------------


def test_jsonl_sink_roundtrip_and_append(tmp_path):
    p = str(tmp_path / "m.jsonl")
    with obs_sink.JsonlSink(p) as s:
        s.emit({"kind": "a", "v": jnp.float32(1.5), "arr": np.arange(3)})
    with obs_sink.JsonlSink(p, append=True) as s:
        s.emit({"kind": "b", "v": 2})
    recs = obs_sink.jsonl_records(p)
    assert recs == [{"kind": "a", "v": 1.5, "arr": [0, 1, 2]},
                    {"kind": "b", "v": 2}]  # device values coerced to Python


def test_csv_sink_locks_columns(tmp_path):
    p = str(tmp_path / "m.csv")
    with obs_sink.CsvSink(p) as s:
        s.emit({"a": 1, "b": 2})
        s.emit({"a": 3, "b": 4, "c": 5})  # extra key dropped
        s.emit({"a": 6})  # missing key left empty
    lines = open(p).read().strip().splitlines()
    assert lines[0] == "a,b"
    assert lines[1:] == ["1,2", "3,4", "6,"]


def test_memory_and_multi_sink():
    mem = obs_sink.MemorySink(capacity=2)
    for i in range(5):
        mem.emit({"i": i})
    assert [r["i"] for r in mem.records] == [3, 4]  # bounded ring

    a, b = obs_sink.MemorySink(), obs_sink.MemorySink()
    multi = obs_sink.MultiSink(a, None, b)  # None sinks are skipped
    multi.emit({"x": jnp.float32(2.0)})
    assert a.records == b.records == [{"x": 2.0}]


# ---------------------------------------------------------------------------
# LoadMonitor: bounded history + sink emission
# ---------------------------------------------------------------------------


def _fake_metrics(E=8, drop=0.25):
    load = np.ones(E)
    load[0] = 2.0
    return SimpleNamespace(load=load, drop_frac=drop)


def test_load_monitor_history_bounded_and_sink_fed():
    sink = obs_sink.MemorySink()
    mon = LoadMonitor(8, history_cap=4, record_every=1, sink=sink)
    for _ in range(10):
        mon.update(_fake_metrics())
    assert len(mon.history) == 4  # ring: old snapshots evicted
    assert mon.history[-1]["step"] == 10
    assert len(sink.records) == 10  # sink saw every recorded snapshot
    assert all(r["kind"] == "load_monitor" for r in sink.records)
    assert sink.records[-1]["imbalance"] > 1.0


def test_load_monitor_record_every_default_and_override():
    mon = LoadMonitor(8, record_every=2)
    for _ in range(6):
        mon.update(_fake_metrics())  # instance default cadence
    assert [r["step"] for r in mon.history] == [2, 4, 6]
    mon.update(_fake_metrics(), record_every=7)
    assert [r["step"] for r in mon.history] == [2, 4, 6, 7]
    mon2 = LoadMonitor(8)  # record_every=0: never records, never grows
    for _ in range(5):
        mon2.update(_fake_metrics())
    assert len(mon2.history) == 0


# ---------------------------------------------------------------------------
# StepStats: measured counters vs modeled HLO bytes
# ---------------------------------------------------------------------------


def test_step_stats_record_and_wire_ratio():
    st = StepStats("train_step", 3, 0.5,
                   counters={"wire_bytes": 50.0, "loss": 1.25},
                   modeled={"all-to-all": 80, "collective-permute": 20,
                            "all-reduce": 999})
    assert st.measured_wire_bytes == 50.0
    assert st.modeled_wire_bytes == 100.0  # a2a + cp only; all-reduce is not wire
    assert st.wire_ratio == 0.5
    rec = st.record()
    assert rec["kind"] == "train_step" and rec["step"] == 3
    assert rec["wall_s"] == 0.5 and rec["loss"] == 1.25
    assert rec["modeled_all_to_all_bytes"] == 80
    assert rec["modeled_all_reduce_bytes"] == 999
    assert rec["wire_measured_over_modeled"] == 0.5

    empty = StepStats("s", 0, 0.1)
    assert empty.measured_wire_bytes is None and empty.wire_ratio is None
    assert "wire_measured_over_modeled" not in empty.record()


def test_modeled_collective_bytes_parses_hlo_text():
    txt = ("%a = f32[128,32]{1,0} all-to-all(%x), dimensions={0}\n"
           "%b = bf16[64]{0} collective-permute-start(%y)\n")
    cb = modeled_collective_bytes(txt)
    assert cb == {"all-to-all": 128 * 32 * 4, "collective-permute": 64 * 2}


# ---------------------------------------------------------------------------
# Serve + train integration
# ---------------------------------------------------------------------------


def test_serve_step_with_metrics_single_device():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.launch.serve import make_serve_step
    from repro.models import lm

    cfg = reduced(get_config("fastmoe-gpt"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cache = lm.init_cache(cfg, 1, cache_len=8)
    tok = jnp.zeros((1, 1), jnp.int32)

    step = make_serve_step(cfg, with_metrics=True)
    logits, cache, md = step(params, tok, jnp.int32(0), cache)
    assert set(md) >= {"drop_frac", "wire_elems", "wire_bytes", "dropped",
                       "shadow_hits", "imbalance"}
    assert float(md["wire_bytes"]) == 0.0  # single device: no wire
    assert float(md["imbalance"]) == 1.0

    plain = make_serve_step(cfg, with_metrics=False)
    _, _, md = plain(params, tok, jnp.int32(0),
                     lm.init_cache(cfg, 1, cache_len=8))
    assert md == {}  # fixed 3-tuple arity: empty metrics, never a 2-tuple


@pytest.mark.tier1
def test_train_cli_metrics_out_and_trace(tmp_path):
    """--metrics_out/--trace end to end on a 1x2 mesh: per-step JSONL
    records carrying the device wire counters + a loadable Chrome trace."""
    mpath = str(tmp_path / "metrics.jsonl")
    tpath = str(tmp_path / "trace.json")
    out = du.run_cli(
        ["repro.launch.train", "--arch", "fastmoe-gpt", "--reduced",
         "--steps", "2", "--batch", "4", "--seq", "32", "--mesh", "1x2",
         "--log_every", "1", "--metrics_out", mpath, "--trace", tpath],
        devices=2)
    assert "done: 2 steps" in out, out

    recs = obs_sink.jsonl_records(mpath)
    steps = [r for r in recs if r.get("kind") == "train_step"]
    assert [r["step"] for r in steps] == [0, 1]
    for r in steps:
        assert r["wall_s"] > 0
        assert r["wire_bytes"] > 0  # distributed a2a: wire traffic measured
        assert r["wire_elems"] > 0
        assert "loss" in r and "imbalance" in r
        # modeled HLO bytes rode along (AOT-lowered step)
        assert any(k.startswith("modeled_") for k in r)

    trace = obs_trace.load_trace(tpath)
    names = {e["name"] for e in trace["traceEvents"]}
    assert "train_step" in names
    assert all(e["ph"] == "X" and e["dur"] >= 0
               for e in trace["traceEvents"])
