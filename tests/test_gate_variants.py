"""Gate variants (noisy top-k, expert-choice) + load monitor + flash kernel.

The property tests at the bottom sweep the routing zoo (ISSUE 10 satellite):
expert-choice capacity exactness, combine-weight normalization across every
router, frozen-router determinism, and gumbel temperature -> argmax
convergence.  They ride tests/_hypothesis_compat — skipped (not faked green)
when hypothesis isn't installed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import MoEConfig
from repro.core import dispatch as D
from repro.core import fmoe
from repro.core.gate import (expert_choice_forward, expert_choice_moe,
                             gate_init, gumbel_topk_forward,
                             noisy_topk_forward, noisy_topk_init,
                             route_tokens, router_init)
from repro.core.monitor import LoadMonitor, expert_placement


CFG = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32)


def test_noisy_topk_deterministic_without_rng():
    params = noisy_topk_init(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g1 = noisy_topk_forward(params, x, CFG)
    g2 = noisy_topk_forward(params, x, CFG)
    np.testing.assert_array_equal(np.asarray(g1.expert_ids),
                                  np.asarray(g2.expert_ids))


def test_noisy_topk_noise_changes_routing():
    params = noisy_topk_init(jax.random.PRNGKey(0), 16, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    g_clean = noisy_topk_forward(params, x, CFG)
    g_noisy = noisy_topk_forward(params, x, CFG, rng=jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(g_clean.expert_ids),
                              np.asarray(g_noisy.expert_ids))
    np.testing.assert_allclose(np.asarray(g_noisy.combine_weights.sum(-1)),
                               1.0, rtol=1e-5)


def test_expert_choice_perfectly_balanced():
    params = {"router": gate_init(jax.random.PRNGKey(0), 16, 8),
              "experts": fmoe._ffn_init(jax.random.PRNGKey(1), 8, 16, 32,
                                        "swiglu", jnp.float32)}
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16))
    y, probs = expert_choice_moe(params, x, CFG, capacity_factor=2.0)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # by construction every expert processes exactly C tokens
    T = 64
    C = int(T * 2.0 / 8)
    idx, w, _, _ = expert_choice_forward(params["router"], x.reshape(-1, 16),
                                         CFG, capacity=C)
    assert idx.shape == (8, C)


def test_load_monitor_tracks_imbalance():
    from repro.core.balance import MoEMetrics
    mon = LoadMonitor(4, ema=0.0)  # no smoothing: snapshot = last update
    balanced = MoEMetrics(jnp.zeros(()), jnp.zeros(()),
                          jnp.full((4,), 0.25), jnp.zeros(()))
    mon.update(balanced)
    assert mon.imbalance == pytest.approx(1.0)
    skewed = MoEMetrics(jnp.zeros(()), jnp.zeros(()),
                        jnp.array([0.7, 0.1, 0.1, 0.1]), jnp.array(0.2))
    mon.update(skewed)
    assert mon.imbalance == pytest.approx(2.8)
    assert mon.snapshot()["drop_ema"] == pytest.approx(0.2)


def test_expert_placement_balances_load():
    load = np.array([8.0, 1.0, 7.0, 2.0, 6.0, 3.0, 5.0, 4.0])
    place = expert_placement(8, 4, load)
    # each worker gets exactly 2 experts
    assert sorted(np.bincount(place, minlength=4).tolist()) == [2, 2, 2, 2]
    worker_loads = np.zeros(4)
    for e, w in enumerate(place):
        worker_loads[w] += load[e]
    # greedy: spread within 25% of ideal (=9.0)
    assert worker_loads.max() <= 9.0 * 1.25


# ---------------------------------------------------------------------------
# Routing-zoo properties (hypothesis; skip when the library is absent)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(T=st.integers(8, 96), E=st.sampled_from([2, 4, 8]),
       cf=st.floats(1.0, 4.0))
def test_expert_choice_capacity_exact_and_dropless(T, E, cf):
    """EC emits the exact per-expert capacity: every expert fills all C
    slots with valid token indices, the layer reports zero drops and a flat
    1/E load at ANY capacity_factor >= 1."""
    cfg = MoEConfig(num_experts=E, top_k=min(2, E), d_expert_hidden=32,
                    router="expert_choice", capacity_factor=cf)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(T * 131 + E), (T, 16))
    C = D.ec_capacity(T, E, cf)
    assert 1 <= C <= T
    idx, w, probs, _ = expert_choice_forward(params["router"], x, cfg,
                                             capacity=C)
    assert idx.shape == (E, C) and w.shape == (E, C)
    assert bool(((idx >= 0) & (idx < T)).all())
    y, m = fmoe.fmoe_apply(params, x, cfg)
    assert float(m.drop_frac) == 0.0
    np.testing.assert_allclose(np.asarray(m.load), 1.0 / E, atol=1e-6)
    assert float(m.aux_loss) == 0.0  # balanced by construction, no aux


@settings(max_examples=25, deadline=None)
@given(router=st.sampled_from(["topk", "noisy_topk", "gumbel", "frozen"]),
       T=st.integers(1, 64), seed=st.integers(0, 2 ** 31 - 1),
       explore=st.booleans())
def test_combine_weights_normalized_across_routers(router, T, seed, explore):
    """Every token-choice router's combine weights sum to 1 per token —
    with or without an exploration rng."""
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32, router=router)
    params = router_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed % 9973 + 1), (T, 16))
    rng = jax.random.PRNGKey(seed) if explore else None
    g = route_tokens(params, x, cfg, rng=rng)
    assert g.expert_ids.shape == (T, 2)
    np.testing.assert_allclose(np.asarray(g.combine_weights.sum(-1)), 1.0,
                               rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_frozen_router_deterministic(seed):
    """After the freeze: same tokens -> same ids regardless of the rng, and
    the ids are invariant to live-gate updates (only w_frozen scores) —
    gate-id tables are stable, the StableMoE stage-2 contract."""
    cfg = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32,
                    router="frozen")
    params = router_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed % 9973 + 1), (48, 16))
    g1 = route_tokens(params, x, cfg, rng=jax.random.PRNGKey(seed))
    g2 = route_tokens(params, x, cfg, rng=jax.random.fold_in(
        jax.random.PRNGKey(seed), 1))
    np.testing.assert_array_equal(np.asarray(g1.expert_ids),
                                  np.asarray(g2.expert_ids))
    # perturbing the live gate w moves nothing: frozen scores only
    bumped = {**params, "w": params["w"] + 3.0}
    g3 = route_tokens(bumped, x, cfg)
    np.testing.assert_array_equal(np.asarray(g1.expert_ids),
                                  np.asarray(g3.expert_ids))
    np.testing.assert_array_equal(np.asarray(g1.combine_weights),
                                  np.asarray(g3.combine_weights))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_gumbel_temperature_converges_to_argmax(seed):
    """temperature -> 0 recovers the deterministic softmax top-k selection
    even WITH an exploration rng; a hot temperature actually explores."""
    cfg_cold = MoEConfig(num_experts=8, top_k=2, d_expert_hidden=32,
                         router="gumbel", router_temperature=1e-7)
    params = router_init(jax.random.PRNGKey(0), 16, cfg_cold)
    x = jax.random.normal(jax.random.PRNGKey(seed % 9973 + 1), (64, 16))
    rng = jax.random.PRNGKey(seed)
    det = gumbel_topk_forward(params, x, cfg_cold)  # rng=None: exact top-k
    cold = gumbel_topk_forward(params, x, cfg_cold, rng=rng)
    np.testing.assert_array_equal(np.asarray(cold.expert_ids),
                                  np.asarray(det.expert_ids))
    np.testing.assert_allclose(np.asarray(cold.combine_weights),
                               np.asarray(det.combine_weights), atol=1e-6)
    cfg_hot = dataclasses.replace(cfg_cold, router_temperature=10.0)
    hot = gumbel_topk_forward(params, x, cfg_hot, rng=rng)
    assert not np.array_equal(np.asarray(hot.expert_ids),
                              np.asarray(det.expert_ids))


# ---------------------------------------------------------------------------
# Flash attention kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [1 << 30, 16])
def test_flash_attention_kernel(dtype, window):
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, KV, dk = 2, 64, 8, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dk)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dk)).astype(dtype)
    y = ops.flash_attention(q, k, v, window=window, bq=16, bk=16)
    y_ref = ref.flash_attention_ref(q, k, v, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol)


def test_flash_attention_non_causal():
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 4, 16))
    v = jax.random.normal(ks[2], (1, 32, 4, 16))
    y = ops.flash_attention(q, k, v, window=1 << 30, causal=False, bq=8, bk=8)
    y_ref = ref.flash_attention_ref(q, k, v, window=1 << 30, causal=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_flash_matches_model_blockwise():
    """The kernel and the model's jnp blockwise scan agree (same window)."""
    import repro.models.attention as A
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 48, 6, 16))
    k = jax.random.normal(ks[1], (2, 48, 2, 16))
    v = jax.random.normal(ks[2], (2, 48, 2, 16))
    y_k = ops.flash_attention(q, k, v, window=12, bq=8, bk=8)
    y_b = A.blockwise_attention(q, k, v, window=12, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_b), atol=2e-5)
