"""End-to-end system tests: real training runs on synthetic data (CPU-scale)
+ dry-run machinery on a small fake mesh."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig
from repro.data import SyntheticLM
from repro.launch.train import make_train_step
from repro.models import lm
from repro.optim import AdamW

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_moe(vocab=256) -> ModelConfig:
    return ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=64, d_ff=128,
        vocab_size=vocab,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert_hidden=64),
        dtype="float32", param_dtype="float32", remat="none")


def test_training_reduces_loss_on_synthetic_data():
    cfg = _tiny_moe()
    data = SyntheticLM(cfg.vocab_size, 32, seed=0)
    opt = AdamW(lr=3e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, warmup=5, total_steps=60))
    losses = []
    for i, batch in enumerate(data.batches(16)):
        if i >= 60:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, (
        losses[:5], losses[-5:])


def test_expert_load_spreads_during_training():
    """The aux loss (paper §6 future work) keeps routing from collapsing."""
    cfg = _tiny_moe()
    data = SyntheticLM(cfg.vocab_size, 32, seed=1)
    opt = AdamW(lr=3e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    drop = None
    for i, batch in enumerate(data.batches(16)):
        if i >= 30:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch, jnp.int32(i))
        drop = float(m["drop_frac"])
    assert drop < 0.5  # routing did not collapse onto one expert


def test_microbatch_accumulation_matches_full_batch():
    cfg = _tiny_moe()
    data = SyntheticLM(cfg.vocab_size, 16, seed=2)
    batch = {k: jnp.asarray(v) for k, v in next(data.batches(8)).items()}
    opt = AdamW(lr=1e-3, clip_norm=None)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    full = jax.jit(make_train_step(cfg, opt))
    micro = jax.jit(make_train_step(cfg, opt, num_microbatches=2))
    p1, _, m1 = full(params, opt.init(params), batch, jnp.int32(0))
    p2, _, m2 = micro(params, opt.init(params), batch, jnp.int32(0))
    # same data, same step: losses match; params close (grad averaging).
    # Microbatching halves the per-gate token count, so expert capacity and
    # drop sets legitimately differ — the tolerance covers routing effects.
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(errs)) < 5e-3


def test_checkpoint_resume_bitexact(tmp_path):
    from repro.checkpoint import restore, save
    cfg = _tiny_moe()
    data = SyntheticLM(cfg.vocab_size, 16, seed=3)
    opt = AdamW(lr=1e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    batches = [next(data.batches(4)) for _ in range(4)]
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]
    for i in range(2):
        params, opt_state, _ = step_fn(params, opt_state, batches[i], jnp.int32(i))
    save(str(tmp_path / "ck"), {"params": params, "opt": opt_state})
    # continue
    pa, oa = params, opt_state
    for i in range(2, 4):
        pa, oa, _ = step_fn(pa, oa, batches[i], jnp.int32(i))
    # resume and continue identically
    st = restore(str(tmp_path / "ck"), {"params": params, "opt": opt_state})
    pb, ob = st["params"], st["opt"]
    for i in range(2, 4):
        pb, ob, _ = step_fn(pb, ob, batches[i], jnp.int32(i))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pa, pb)


def test_dryrun_machinery_small_mesh():
    """lower_combo on a tiny fake mesh for each step kind (subprocess)."""
    script = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_config, reduced
        from repro.configs.base import InputShape
        from repro.launch.dryrun import lower_combo
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(2, 4)
        cfg = reduced(get_config("arctic-480b"))
        for shape in [InputShape("t", 64, 8, "train"),
                      InputShape("p", 64, 8, "prefill"),
                      InputShape("d", 64, 8, "decode")]:
            lowered = lower_combo(cfg, shape, mesh)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # some jax versions return [dict]
                cost = cost[0]
            print(shape.mode, "ok flops=", cost.get("flops", 0))
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("ok") == 3


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
      %ag.1 = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
      %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
      %ars = f32[4]{0} all-reduce-start(f32[4]{0} %z)
      %ard = f32[4]{0} all-reduce-done(f32[4]{0} %ars)
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 4 + 16
    assert got["all-gather"] == 64 * 2
    assert got["all-to-all"] == 2 * 64 * 4
