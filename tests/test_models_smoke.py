"""Per-assigned-architecture smoke tests: reduced variant (2 layers,
d_model<=512, <=4 experts), one forward + one train step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced
from repro.launch.train import make_train_step
from repro.models import lm
from repro.optim import AdamW

BATCH, SEQ = 2, 16


def _batch(cfg, seed=1):
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (BATCH, SEQ),
                                      0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        b["patches"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (BATCH, cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (BATCH, cfg.encoder.num_frames, cfg.d_model))
    return b


@pytest.fixture(scope="module", params=ASSIGNED)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def test_reduced_constraints(arch_setup):
    name, cfg, _ = arch_setup
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


def test_forward_shapes_no_nan(arch_setup):
    name, cfg, params = arch_setup
    b = _batch(cfg)
    logits, metrics = lm.forward(params, cfg, b["tokens"],
                                 frames=b.get("frames"), patches=b.get("patches"))
    S = SEQ + (cfg.num_patches if cfg.frontend == "vision" else 0)
    assert logits.shape == (BATCH, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_one_train_step_no_nan(arch_setup):
    name, cfg, params = arch_setup
    opt = AdamW(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    b = _batch(cfg)
    new_params, opt_state, m = step_fn(params, opt_state, b, jnp.int32(0))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


def test_loss_decreases_two_steps(arch_setup):
    """Sanity: repeated steps on one batch reduce loss (overfit signal)."""
    name, cfg, params = arch_setup
    opt = AdamW(lr=5e-3, weight_decay=0.0)
    step_fn = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    b = _batch(cfg)
    losses = []
    for i in range(4):
        params, opt_state, m = step_fn(params, opt_state, b, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
