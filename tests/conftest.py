"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; multi-device tests
spawn subprocesses with their own flags (tests/test_distributed.py)."""
import jax
import pytest

# Import-safe, single-device, fast modules — the tier-1 subset scripts/ci.sh
# runs on every change (the full suite adds multi-process + model smokes).
TIER1_MODULES = {
    "test_calibrate", "test_dispatch", "test_fmoe", "test_fused_ffn",
    "test_fused_ffn_bwd", "test_gate", "test_gate_variants",
    "test_hier_a2a", "test_hlo_regression", "test_obs", "test_per_layer",
    "test_placement", "test_ragged_a2a", "test_resilience",
    "test_router_zoo", "test_scheduler", "test_serve",
    "test_sharding_rules", "test_substrate",
}


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "tier1: fast import-safe subset run by scripts/ci.sh")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in TIER1_MODULES:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
