"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; multi-device tests
spawn subprocesses with their own flags (tests/test_distributed.py)."""
import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
