"""Dynamic expert placement & shadowing (closing FastMoE §6's open loop).

plan.py      — ExpertPlacement + roofline cost model + PlacementController
migrate.py   — permute live params / optimizer state between layouts
shadow.py    — replicated hot-expert execution, skipped in the all-to-all
calibrate.py — cost-model constants measured from benchmarks/results
"""
from repro.placement.calibrate import (CostConstants, calibrate_constants,
                                       load_calibration)
from repro.placement.migrate import (from_logical, migrate,
                                     router_index_table, to_logical)
from repro.placement.plan import (ExpertPlacement, PlacementController,
                                  identity_placement, placement_cost,
                                  plan_placement)
from repro.placement.shadow import (ShadowSpec, merge_outputs, shadow_spec,
                                    split_buffer)

__all__ = [
    "CostConstants", "ExpertPlacement", "PlacementController", "ShadowSpec",
    "calibrate_constants", "from_logical", "identity_placement",
    "load_calibration", "merge_outputs", "migrate", "placement_cost",
    "plan_placement", "router_index_table", "shadow_spec", "split_buffer",
    "to_logical",
]
