"""Dynamic expert placement & shadowing (closing FastMoE §6's open loop).

plan.py      — ExpertPlacement / PerLayerPlacement + roofline cost model +
               PlacementController (per-layer aware)
migrate.py   — permute live params / optimizer state between layouts
               (per-layer plans permute each layer's slice independently)
shadow.py    — replicated hot-expert execution: skipped in the all-to-all
               (train) and in the psum reduction (decode)
calibrate.py — cost-model constants measured from benchmarks/results
"""
from repro.placement.calibrate import (CostConstants, calibrate_constants,
                                       load_calibration)
from repro.placement.migrate import (from_logical, migrate,
                                     router_index_table, to_logical)
from repro.placement.plan import (ExpertPlacement, PerLayerPlacement,
                                  PlacementController, identity_per_layer,
                                  identity_placement, per_layer_cost,
                                  per_layer_placement, placement_cost,
                                  plan_placement, plan_placement_per_layer)
from repro.placement.shadow import (ShadowSpec, merge_outputs, shadow_only,
                                    shadow_spec, split_buffer)

__all__ = [
    "CostConstants", "ExpertPlacement", "PerLayerPlacement",
    "PlacementController", "ShadowSpec", "calibrate_constants",
    "from_logical", "identity_per_layer", "identity_placement",
    "load_calibration", "merge_outputs", "migrate", "per_layer_cost",
    "per_layer_placement", "placement_cost", "plan_placement",
    "plan_placement_per_layer", "router_index_table", "shadow_only",
    "shadow_spec", "split_buffer", "to_logical",
]
