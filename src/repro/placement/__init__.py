"""Dynamic expert placement & shadowing (closing FastMoE §6's open loop).

plan.py    — ExpertPlacement + roofline cost model + PlacementController
migrate.py — permute live params / optimizer state between layouts
shadow.py  — replicated hot-expert execution, skipped in the all-to-all
"""
from repro.placement.migrate import (from_logical, migrate,
                                     router_index_table, to_logical)
from repro.placement.plan import (ExpertPlacement, PlacementController,
                                  identity_placement, placement_cost,
                                  plan_placement)
from repro.placement.shadow import (ShadowSpec, merge_outputs, shadow_spec,
                                    split_buffer)

__all__ = [
    "ExpertPlacement", "PlacementController", "ShadowSpec", "from_logical",
    "identity_placement", "merge_outputs", "migrate", "placement_cost",
    "plan_placement", "router_index_table", "shadow_spec", "split_buffer",
    "to_logical",
]
