"""Shadowed ("hot") expert execution — the data-plane half of placement.

A shadowed expert is replicated on every expert-parallel rank: its weights
ride into the shard_map region replicated (the broadcast), each rank computes
it on the rank's *own* tokens, and its buffer rows are skipped in the
all-to-all payload.  Per-rank FLOPs are unchanged (the owner no longer
computes the mp-fanned rows for that expert; every rank computes its C rows
instead), so shadowing is a pure communication win paid for by weight-sync
(see plan.placement_cost).

Physical layout contract (plan.ExpertPlacement): owned experts occupy
physical slots ``[0, num_owned)`` in contiguous per-rank blocks; shadowed
experts occupy ``[num_owned, E)``.  The a2a buffer covers only the owned
slots, at a capacity the planner may shrink to the residual load peak.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.placement.plan import ExpertPlacement


class ShadowSpec(NamedTuple):
    """Static split geometry for one (placement, per-rank capacity) pair."""

    num_experts: int
    num_owned: int
    main_capacity: int  # a2a buffer rows per owned expert (<= shadow_capacity)
    shadow_capacity: int  # local buffer rows per shadowed expert

    @property
    def num_shadow(self) -> int:
        return self.num_experts - self.num_owned

    @property
    def width(self) -> int:
        """Dispatch buffer width (max per-expert capacity in use)."""
        if self.num_shadow == 0:
            return self.main_capacity
        return max(self.main_capacity, self.shadow_capacity)

    @property
    def capacities(self) -> np.ndarray:
        """Per-expert capacity vector in physical order (static)."""
        caps = np.full(self.num_experts, self.main_capacity, np.int32)
        caps[self.num_owned:] = self.shadow_capacity
        return caps

    def a2a_elems(self, d_model: int) -> int:
        """Per-rank elements exchanged in ONE a2a direction (for reporting)."""
        return self.num_owned * self.main_capacity * d_model


def shadow_spec(placement: Optional[ExpertPlacement], num_experts: int,
                capacity: int) -> ShadowSpec:
    """Geometry under ``placement`` (identity geometry when None)."""
    if placement is None:
        return ShadowSpec(num_experts, num_experts, capacity, capacity)
    if placement.num_experts != num_experts:
        raise ValueError((placement.num_experts, num_experts))
    return ShadowSpec(num_experts, placement.num_owned,
                      placement.main_capacity(capacity), capacity)


def split_buffer(buf: jnp.ndarray, spec: ShadowSpec):
    """(E, width, d) dispatch buffer -> (owned a2a part, local shadow part)."""
    main = buf[:spec.num_owned, :spec.main_capacity]
    shadow = buf[spec.num_owned:, :spec.shadow_capacity]
    return main, shadow


def merge_outputs(out_main: jnp.ndarray, out_shadow: Optional[jnp.ndarray],
                  spec: ShadowSpec) -> jnp.ndarray:
    """Reassemble expert outputs into the (E, width, dout) combine buffer."""
    d_out = out_main.shape[-1]
    if spec.num_shadow == 0 and spec.main_capacity == spec.width:
        return out_main
    out = jnp.zeros((spec.num_experts, spec.width, d_out), out_main.dtype)
    out = out.at[:spec.num_owned, :spec.main_capacity].set(out_main)
    if out_shadow is not None and spec.num_shadow:
        out = out.at[spec.num_owned:, :spec.shadow_capacity].set(out_shadow)
    return out


def shadow_only(out_shadow: jnp.ndarray, spec: ShadowSpec) -> jnp.ndarray:
    """(S, shadow_capacity, dout) shadow outputs alone in a zeroed (E, width,
    dout) combine buffer — the decode (psum) path's local addend: shadowed
    slots are excluded from the cross-rank reduction and served from this
    buffer instead (every model-axis rank holds the same tokens there, so
    the local contribution is identical on all of them)."""
    d_out = out_shadow.shape[-1]
    out = jnp.zeros((spec.num_experts, spec.width, d_out), out_shadow.dtype)
    return out.at[spec.num_owned:, :spec.shadow_capacity].set(out_shadow)
