"""Expert placement plans — turning measured load into an executable layout.

FastMoE §6 leaves the load-balance *actuator* as future work ("the work of
load-balance monitor ... is in progress"); this module closes the loop the
way the StableMoE lineage does (imbalanced all2all / expert allreduce /
model migration): from a :class:`repro.core.monitor.LoadMonitor` load vector,
compute an :class:`ExpertPlacement` that

* permutes logical experts into a *physical* order so each rank owns a
  load-balanced contiguous block (the greedy placer from core/monitor.py);
* marks the hottest experts as **shadowed**: replicated on every rank,
  computed locally from broadcast weights, and skipped in the all-to-all
  payload (repro/placement/shadow.py);
* optionally shrinks the a2a capacity buffer to fit the residual (non-shadow)
  load peak.

The shadow set is chosen by a roofline cost model (launch/roofline.py
constants): all-to-all bytes saved per step vs. the per-step cost of keeping
the replicas in sync (grad all-reduce of shadow weights + amortized weight
broadcast + extra HBM weight reads).

Routing semantics are unchanged: the router still scores *logical* experts;
``logical_to_physical`` is the index table applied after top-k (see
core/fmoe.py), and migrate.py moves params/optimizer state between layouts.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core.monitor import expert_placement as greedy_placement
from repro.placement.calibrate import CostConstants


def _round8(n: float) -> int:
    return max(8, int(-(-int(n) // 8) * 8))


class ExpertPlacement(NamedTuple):
    """A physical expert layout for ``num_ranks`` expert-parallel ranks.

    Physical slots ``[0, E - num_shadow)`` are owned experts, laid out as
    contiguous per-rank blocks of ``(E - num_shadow) // num_ranks``; slots
    ``[E - num_shadow, E)`` are shadowed (replicated on every rank, hottest
    first).  ``num_shadow`` is always a multiple of ``num_ranks`` so the
    owned block stays divisible for the all-to-all reshape.
    """

    num_experts: int
    num_ranks: int
    physical_to_logical: tuple  # len E — logical expert in each physical slot
    num_shadow: int = 0
    capacity_scale: float = 1.0  # a2a buffer capacity multiplier (<= 1)

    @property
    def num_owned(self) -> int:
        return self.num_experts - self.num_shadow

    @property
    def logical_to_physical(self) -> np.ndarray:
        l2p = np.empty(self.num_experts, np.int32)
        l2p[np.asarray(self.physical_to_logical, np.int32)] = np.arange(
            self.num_experts, dtype=np.int32)
        return l2p

    @property
    def expert_to_rank(self) -> np.ndarray:
        """Owning rank per *logical* expert; -1 for shadowed (all ranks)."""
        per_rank = self.num_owned // self.num_ranks
        rank_of_phys = np.full(self.num_experts, -1, np.int32)
        rank_of_phys[:self.num_owned] = (
            np.arange(self.num_owned, dtype=np.int32) // per_rank)
        return rank_of_phys[self.logical_to_physical]

    @property
    def replication(self) -> np.ndarray:
        """Replication degree per logical expert (1 owned, num_ranks shadow)."""
        rep = np.where(self.expert_to_rank < 0, self.num_ranks, 1)
        return rep.astype(np.int32)

    @property
    def is_identity(self) -> bool:
        return (self.num_shadow == 0 and self.capacity_scale == 1.0
                and list(self.physical_to_logical)
                == list(range(self.num_experts)))

    def main_capacity(self, capacity: int) -> int:
        """a2a buffer capacity after the planner's shrink (multiple of 8)."""
        if self.capacity_scale >= 1.0:
            return capacity
        return min(capacity, _round8(capacity * self.capacity_scale))


def identity_placement(num_experts: int, num_ranks: int) -> ExpertPlacement:
    """The seed layout: logical == physical, contiguous blocks, no shadows."""
    return ExpertPlacement(num_experts, num_ranks,
                           tuple(range(num_experts)))


class PerLayerPlacement(NamedTuple):
    """One :class:`ExpertPlacement` per MoE layer, sharing a *geometry*.

    Expert load skew is per layer (DeepSpeed's multitask MoE measurements),
    so each layer gets its own permutation and its own shadowed hot set.
    The layer stack is applied with ``jax.lax.scan`` over homogeneous params,
    which forces every layer's plan to share the static *geometry* —
    ``(num_experts, num_ranks, num_shadow, capacity_scale)`` — while the
    per-layer logical→physical tables ride through the scan as a stacked
    ``(L, E)`` index array (see models/lm.py).  migrate.py permutes each
    layer's expert slice of a stacked ``(L, E, ...)`` tree independently.
    """

    layers: tuple  # tuple[ExpertPlacement, ...], geometry-identical

    def validate(self) -> "PerLayerPlacement":
        if not self.layers:
            raise ValueError("PerLayerPlacement needs at least one layer")
        g = self.layers[0]
        for i, p in enumerate(self.layers):
            if ((p.num_experts, p.num_ranks, p.num_shadow, p.capacity_scale)
                    != (g.num_experts, g.num_ranks, g.num_shadow,
                        g.capacity_scale)):
                raise ValueError(
                    f"layer {i} geometry {p[:2] + p[3:]} differs from layer 0 "
                    f"{g[:2] + g[3:]} — scan needs one shared geometry")
        return self

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def num_experts(self) -> int:
        return self.layers[0].num_experts

    @property
    def num_ranks(self) -> int:
        return self.layers[0].num_ranks

    @property
    def num_shadow(self) -> int:
        return self.layers[0].num_shadow

    @property
    def num_owned(self) -> int:
        return self.layers[0].num_owned

    @property
    def capacity_scale(self) -> float:
        return self.layers[0].capacity_scale

    @property
    def geometry(self) -> ExpertPlacement:
        """A representative single-layer plan carrying the shared static
        geometry (what DistConfig.placement holds inside the layer scan)."""
        return self.layers[0]

    @property
    def is_identity(self) -> bool:
        return all(p.is_identity for p in self.layers)

    @property
    def logical_to_physical(self) -> np.ndarray:
        """(L, E) stacked gate-id tables (one row per layer)."""
        return np.stack([p.logical_to_physical for p in self.layers])

    @property
    def physical_to_logical(self) -> np.ndarray:
        return np.stack([np.asarray(p.physical_to_logical, np.int32)
                         for p in self.layers])

    def layer(self, i: int) -> ExpertPlacement:
        return self.layers[i]


def per_layer_placement(layers) -> PerLayerPlacement:
    """Validated constructor for a geometry-shared per-layer plan."""
    return PerLayerPlacement(tuple(layers)).validate()


def identity_per_layer(num_experts: int, num_ranks: int,
                       num_layers: int) -> PerLayerPlacement:
    return PerLayerPlacement(
        (identity_placement(num_experts, num_ranks),) * num_layers)


# ---------------------------------------------------------------------------
# Cost model (roofline constants; seconds per train step)
# ---------------------------------------------------------------------------


class PlacementCost(NamedTuple):
    a2a_s: float  # all-to-all payload time
    sync_s: float  # shadow-weight grad all-reduce + amortized broadcast
    hbm_s: float  # extra HBM reads for replicated shadow weights
    drop_frac: float  # modeled dropped-token fraction (quality proxy)

    @property
    def total_s(self) -> float:
        return self.a2a_s + self.sync_s + self.hbm_s


def placement_cost(place: ExpertPlacement, load: np.ndarray, *,
                   d_model: int, d_hidden: int, capacity: int,
                   capacity_factor: float = 1.0, bytes_per_elem: int = 4,
                   train: bool = True, replan_every: int = 200,
                   constants: Optional[CostConstants] = None) -> PlacementCost:
    """Modeled per-step cost of executing under ``place`` with ``load``.

    a2a term: dispatch + return payload of the *owned* buffer, forward and
    (in training) backward.  sync term: shadow experts become replicated
    parameters, so their grads all-reduce every step and their weights
    broadcast once per replan interval.  hbm term: every rank streams the
    shadow weights in addition to its own shard.

    ``constants`` prices the terms; defaults to the static v5e roofline —
    pass :func:`repro.placement.calibrate.load_calibration` output to use
    bandwidths measured on this machine instead.
    """
    c = constants if constants is not None else CostConstants()
    load = np.asarray(load, np.float64)
    load = load / max(load.sum(), 1e-12)
    E, S = place.num_experts, place.num_shadow
    c_main = place.main_capacity(capacity)
    dirs = 4.0 if train else 2.0  # dispatch+return, x2 for backward
    a2a_bytes = place.num_owned * c_main * d_model * bytes_per_elem
    a2a_s = dirs * a2a_bytes / c.ici_bw

    w_elems = 3 * d_model * d_hidden  # swiglu-shaped expert: 3 projections
    sync_s = 0.0
    hbm_s = 0.0
    if S:
        shadow_w_bytes = S * w_elems * bytes_per_elem
        if train:  # replicated weights => grad all-reduce (2 hops of a ring)
            sync_s += 2.0 * shadow_w_bytes / c.ici_bw
        sync_s += shadow_w_bytes / c.ici_bw / max(replan_every, 1)
        hbm_s += shadow_w_bytes / c.hbm_bw
    # quality proxy: tokens beyond an expert's capacity are dropped.  Owned
    # experts see the (possibly shrunk) a2a capacity; shadowed experts keep
    # the full per-rank buffer.
    owned = place.expert_to_rank >= 0
    caps = np.where(owned, c_main, capacity).astype(np.float64)
    # capacity = cf * t*k / E, so per-rank arrivals to expert e are
    # load_e * t*k = load_e * E * capacity / cf (cf=1 -> conservative)
    per_rank_arrivals = load * capacity * E / max(capacity_factor, 1e-9)
    over = np.maximum(per_rank_arrivals - caps, 0.0).sum()
    drop = float(over / max(per_rank_arrivals.sum(), 1e-12))
    # no peak_flops charge: shadow compute per rank replaces the owner's
    # mp-fanned buffer rows one-for-one (E*C slots per rank either way), so
    # the FLOP term cancels; c.peak_flops is there for future cost models.
    return PlacementCost(a2a_s, sync_s, hbm_s, drop)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


def _residual_scale(load: np.ndarray, owned: np.ndarray, capacity: int) -> float:
    """Capacity multiplier covering the residual (non-shadow) load peak.

    Baseline C is capacity_factor x the fair share 1/E, so an expert at load
    fraction f needs f*E*C slots for the same headroom; size the a2a buffer
    to the residual peak.
    """
    E = load.size
    f_max = float(load[owned].max()) if owned.size else 0.0
    return min(1.0, max(f_max * E, 8.0 / max(capacity, 8)))


def _build_plan(load: np.ndarray, num_ranks: int, S: int,
                scale: float) -> ExpertPlacement:
    """Shadow the S hottest experts, greedy-balance the rest into contiguous
    per-rank blocks (the shared build step of both planners)."""
    E = load.size
    hot_first = np.argsort(-load, kind="stable")
    shadow = hot_first[:S]
    owned = np.sort(hot_first[S:])
    # balanced contiguous blocks: greedy-assign owned experts to ranks,
    # then lay each rank's experts out contiguously (physical order)
    ranks = np.asarray(greedy_placement(owned.size, num_ranks,
                                        load[owned]), np.int64)
    phys = [int(e) for r in range(num_ranks)
            for e in owned[ranks == r]]
    phys += [int(e) for e in shadow]
    return ExpertPlacement(E, num_ranks, tuple(phys), int(S), float(scale))


def _norm_load(load: np.ndarray) -> np.ndarray:
    load = np.asarray(load, np.float64)
    return load / max(load.sum(), 1e-12)


def plan_placement(load: np.ndarray, num_ranks: int, *, d_model: int,
                   d_hidden: int, capacity: int, capacity_factor: float = 1.0,
                   bytes_per_elem: int = 4, train: bool = True,
                   replan_every: int = 200, max_shadow_frac: float = 0.5,
                   shrink_capacity: bool = True,
                   constants: Optional[CostConstants] = None) -> ExpertPlacement:
    """Choose shadow set + permutation minimizing the modeled step cost.

    Scans shadow counts S in multiples of ``num_ranks`` (so the owned block
    stays divisible), shadowing the hottest experts first.  For each S the
    a2a capacity may shrink to the residual load peak (no worse drop rate
    than the baseline buffer).  Falls back to a pure load-balancing
    permutation (S=0) when shadowing doesn't pay.
    """
    load = _norm_load(load)
    E = load.size
    if E % num_ranks:
        raise ValueError(f"num_experts {E} not divisible by ranks {num_ranks}")
    hot_first = np.argsort(-load, kind="stable")

    def build(S: int) -> ExpertPlacement:
        scale = 1.0
        if shrink_capacity and S:
            scale = _residual_scale(load, np.sort(hot_first[S:]), capacity)
        return _build_plan(load, num_ranks, S, scale)

    kw = dict(d_model=d_model, d_hidden=d_hidden, capacity=capacity,
              capacity_factor=capacity_factor, bytes_per_elem=bytes_per_elem,
              train=train, replan_every=replan_every, constants=constants)
    base = build(0)
    # drops are a quality regression, not a time cost: never trade them
    base_drop = placement_cost(base, load, **kw).drop_frac
    best, best_cost = None, np.inf
    max_s = int(max_shadow_frac * E) // num_ranks * num_ranks
    for S in range(0, max_s + 1, num_ranks):
        cand = base if S == 0 else build(S)
        cost = placement_cost(cand, load, **kw)
        if cost.drop_frac > base_drop + 1e-9:
            continue
        if cost.total_s < best_cost - 1e-12:
            best, best_cost = cand, cost.total_s
    return best if best is not None else base


def per_layer_cost(plan: PerLayerPlacement, load: np.ndarray,
                   **kw) -> PlacementCost:
    """Summed modeled per-step cost of an (L,)-stacked plan under (L, E) load.

    Each layer's shadow weights are distinct parameters, so the sync and hbm
    terms are charged per layer; the weight-broadcast amortization shares one
    replan interval across the whole stack (``replan_every`` divides each
    layer's broadcast term — a single replan migrates all L layers at once).
    """
    load = np.asarray(load, np.float64)
    if load.ndim != 2 or load.shape[0] != plan.num_layers:
        raise ValueError(f"load shape {load.shape} != (L={plan.num_layers}, E)")
    parts = [placement_cost(p, load[i], **kw)
             for i, p in enumerate(plan.layers)]
    return PlacementCost(sum(p.a2a_s for p in parts),
                         sum(p.sync_s for p in parts),
                         sum(p.hbm_s for p in parts),
                         float(np.mean([p.drop_frac for p in parts])))


def plan_placement_per_layer(load: np.ndarray, num_ranks: int, *,
                             d_model: int, d_hidden: int, capacity: int,
                             capacity_factor: float = 1.0,
                             bytes_per_elem: int = 4, train: bool = True,
                             replan_every: int = 200,
                             max_shadow_frac: float = 0.5,
                             shrink_capacity: bool = True,
                             constants: Optional[CostConstants] = None,
                             ) -> PerLayerPlacement:
    """Per-layer planner: one permutation + shadow *set* per layer, one
    shared geometry.

    The scan over the layer stack needs static shapes, so the shadow count S
    and capacity scale are chosen *jointly* — the S minimizing the summed
    per-layer cost (hot layers' a2a savings subsidize cool ones) — while
    each layer independently picks *which* experts to shadow (its own
    hottest) and how to permute the rest (its own greedy balance).  The
    shared capacity scale is the max of the per-layer residual peaks, so no
    layer drops more than it would under the baseline buffer.

    With identical per-layer loads this degenerates to ``plan_placement``
    stacked L times (the acceptance bit-exactness case).
    """
    load = np.asarray(load, np.float64)
    if load.ndim != 2:
        raise ValueError(f"per-layer load must be (L, E), got {load.shape}")
    L, E = load.shape
    if E % num_ranks:
        raise ValueError(f"num_experts {E} not divisible by ranks {num_ranks}")
    rows = [_norm_load(load[i]) for i in range(L)]
    hot = [np.argsort(-r, kind="stable") for r in rows]

    def build(S: int) -> PerLayerPlacement:
        scale = 1.0
        if shrink_capacity and S:
            scale = max(_residual_scale(rows[i], np.sort(hot[i][S:]), capacity)
                        for i in range(L))
        return PerLayerPlacement(tuple(
            _build_plan(rows[i], num_ranks, S, scale) for i in range(L)))

    kw = dict(d_model=d_model, d_hidden=d_hidden, capacity=capacity,
              capacity_factor=capacity_factor, bytes_per_elem=bytes_per_elem,
              train=train, replan_every=replan_every, constants=constants)
    base = build(0)
    base_drop = per_layer_cost(base, load, **kw).drop_frac
    best, best_cost = None, np.inf
    max_s = int(max_shadow_frac * E) // num_ranks * num_ranks
    for S in range(0, max_s + 1, num_ranks):
        cand = base if S == 0 else build(S)
        cost = per_layer_cost(cand, load, **kw)
        if cost.drop_frac > base_drop + 1e-9:
            continue
        if cost.total_s < best_cost - 1e-12:
            best, best_cost = cand, cost.total_s
    return (best if best is not None else base).validate()


# ---------------------------------------------------------------------------
# Replan controller (the train.py hook's brain)
# ---------------------------------------------------------------------------


class PlacementController:
    """Periodic replan driver fed by a LoadMonitor.

    Every ``every`` steps, recompute a plan from the monitor's load EMA and
    return it iff the modeled step time improves on the current plan by at
    least ``min_gain`` (relative).  The caller owns executing the migration
    (see migrate.py) and swapping the jitted step function.

    ``num_layers > 0`` switches to per-layer mode: plans come from
    :func:`plan_placement_per_layer` fed by the monitor's ``(L, E)``
    layer-load EMA, and ``current`` is a :class:`PerLayerPlacement`.
    """

    def __init__(self, monitor, num_ranks: int, *, d_model: int,
                 d_hidden: int, capacity: int, capacity_factor: float = 1.0,
                 every: int = 200, min_gain: float = 0.02, train: bool = True,
                 shrink_capacity: bool = True, bytes_per_elem: int = 4,
                 num_layers: int = 0, flat_tol: float = 0.02,
                 constants: Optional[CostConstants] = None):
        self.monitor = monitor
        self.num_ranks = num_ranks
        self.every = every
        self.min_gain = min_gain
        self.flat_tol = flat_tol
        self.num_layers = num_layers
        self.constants = constants if constants is not None else CostConstants()
        self.kw = dict(d_model=d_model, d_hidden=d_hidden, capacity=capacity,
                       capacity_factor=capacity_factor, train=train,
                       replan_every=every, shrink_capacity=shrink_capacity,
                       bytes_per_elem=bytes_per_elem, constants=self.constants)
        if num_layers:
            if getattr(monitor, "num_layers", 0) != num_layers:
                raise ValueError(
                    f"per-layer controller ({num_layers} layers) needs a "
                    f"LoadMonitor(num_layers={num_layers})")
            self.current = identity_per_layer(monitor.num_experts, num_ranks,
                                              num_layers)
        else:
            self.current = identity_placement(monitor.num_experts, num_ranks)
        self.replans = 0
        self.rollbacks = 0
        self.flat_skips = 0  # replan ticks short-circuited by flat load
        # plans that regressed post-migration and were rolled back
        # (launch.train.ReplanHook probation): never propose them again
        self._blacklist: set = set()

    def _cost(self, plan, load) -> float:
        ckw = {k: v for k, v in self.kw.items() if k != "shrink_capacity"}
        if self.num_layers:
            return per_layer_cost(plan, load, **ckw).total_s
        return placement_cost(plan, load, **ckw).total_s

    def blacklist(self, plan) -> None:
        """Bar a plan from ever being proposed again (post-rollback).  Plans
        are NamedTuples of hashables, so the plan itself is the key."""
        self._blacklist.add(plan)

    def rollback(self, to_plan, bad_plan) -> None:
        """Record a probation rollback: the live layout returns to
        ``to_plan`` and ``bad_plan`` joins the blacklist."""
        self.current = to_plan
        self.blacklist(bad_plan)
        self.rollbacks += 1

    def _is_flat(self, load) -> bool:
        """True when every expert's share is within ``flat_tol`` of uniform.

        Expert-choice routing produces exactly this by construction (1/E per
        expert), and well-balanced token-choice gates approach it — either
        way no layout can beat the identity-ish one we already run, so the
        planner short-circuits instead of burning a plan+cost pass."""
        load = np.asarray(load, np.float64)
        rows = load if load.ndim == 2 else load[None, :]
        for row in rows:
            tot = row.sum()
            if tot <= 0:
                return False
            share = row / tot
            if share.max() * row.shape[0] > 1.0 + self.flat_tol:
                return False
        return True

    def maybe_replan(self, step: int):
        """New plan to migrate to, or None to keep the current layout."""
        if self.every <= 0 or step == 0 or step % self.every:
            return None
        if self.num_layers:
            load = self.monitor.load_ema_layers
        else:
            load = self.monitor.load_ema
        if self._is_flat(load):
            # flat load (expert-choice by construction, or a converged gate):
            # no placement can improve on uniform — keep the current layout.
            self.flat_skips += 1
            return None
        if self.num_layers:
            cand = plan_placement_per_layer(load, self.num_ranks, **self.kw)
        else:
            cand = plan_placement(load, self.num_ranks, **self.kw)
        if cand in self._blacklist:
            return None
        now = self._cost(self.current, load)
        new = self._cost(cand, load)
        if new < now * (1.0 - self.min_gain) and cand != self.current:
            self.current = cand
            self.replans += 1
            return cand
        return None
