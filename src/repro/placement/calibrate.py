"""Calibrate placement cost-model constants from measured benchmark results.

The roofline cost model in :mod:`repro.placement.plan` priced candidate
layouts with hard-coded TPU v5e constants (launch/roofline.py) even when the
repo had measured numbers sitting in ``benchmarks/results/results.json``
(the ROADMAP follow-on).  This module closes that gap: a
:class:`CostConstants` bundle threads through ``placement_cost`` /
``plan_placement`` / ``PlacementController``, and
:func:`calibrate_constants` derives *effective* constants from the results
file —

* wire bandwidth from fig8: placement-on shrinks the exchanged buffer, so
  (bytes_off - bytes_on) / (t_off - t_on) is the marginal seconds-per-byte
  the planner is actually trading against;
* peak FLOPs from fig3: the best measured large-batch GEMM throughput.

Measurements that are non-informative are rejected and the v5e roofline
value is kept — calibration must never make the planner *worse* than the
static model, only tighter where the data supports it.  Non-informative
means: the time delta goes the wrong way, the derived value falls outside
sanity clamps, or — crucially — the row was *not measured on a real
accelerator* (rows carry a ``backend`` tag; CPU fake-device "collectives"
are memcpys, and pricing real ICI traffic at memcpy bandwidth would make
the planner grossly over-replicate shadow experts).
"""
from __future__ import annotations

import json
import os
from typing import NamedTuple, Optional

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

# sanity clamps: outside this range a "measurement" is an artifact, not a
# bandwidth (covers everything from PCIe-ish to beyond-ICI interconnects)
_BW_MIN, _BW_MAX = 1e7, 1e14
_FLOPS_MIN, _FLOPS_MAX = 1e9, 1e18

# only rows measured on a real accelerator may calibrate wire/compute
# constants; CPU (fake-device) benchmark rows time memcpys, not a wire
_REAL_BACKENDS = ("tpu", "gpu")


class CostConstants(NamedTuple):
    """Hardware constants the placement cost model prices plans with."""

    ici_bw: float = ICI_BW  # bytes/s across the expert-parallel wire
    hbm_bw: float = HBM_BW  # bytes/s per chip
    peak_flops: float = PEAK_FLOPS  # flop/s per chip
    source: str = "v5e-roofline"  # provenance, for logs/repr


def default_results_path() -> str:
    """`benchmarks/results/results.json` relative to the repo checkout."""
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "benchmarks", "results", "results.json")


def calibrate_constants(results: dict, *,
                        bytes_per_elem: int = 4) -> CostConstants:
    """Effective constants from a ``results.json``-shaped dict.

    Falls back field-by-field to the v5e roofline values whenever the
    corresponding measurement is absent or non-informative.
    """
    srcs = []
    ici = ICI_BW
    for row in results.get("fig8", []):
        if row.get("backend") not in _REAL_BACKENDS:
            continue  # fake-device memcpy timing is not a wire measurement
        dt_s = (row.get("us_off", 0.0) - row.get("us_on", 0.0)) * 1e-6
        delems = row.get("a2a_elems_off", 0) - row.get("a2a_elems_on", 0)
        # fig8 times one forward pass: dispatch + return = 2 payload moves
        dbytes = 2.0 * delems * bytes_per_elem
        if dt_s <= 0 or dbytes <= 0:
            continue  # shrinking the buffer didn't pay: wire not the limiter
        bw = dbytes / dt_s
        if _BW_MIN <= bw <= _BW_MAX:
            ici = bw
            srcs.append("fig8")
            break
    flops = PEAK_FLOPS
    fig3 = [r.get("gflops", 0.0) for r in results.get("fig3", [])
            if r.get("backend") in _REAL_BACKENDS]
    if fig3:
        best = max(fig3) * 1e9
        if _FLOPS_MIN <= best <= _FLOPS_MAX:
            flops = best
            srcs.append("fig3")
    return CostConstants(ici, HBM_BW, flops,
                         "measured:" + "+".join(srcs) if srcs
                         else "v5e-roofline")


def load_calibration(path: Optional[str] = None) -> CostConstants:
    """CostConstants from a results file; roofline defaults if unreadable."""
    path = path or default_results_path()
    try:
        with open(path) as f:
            results = json.load(f)
    except (OSError, ValueError):
        return CostConstants()
    return calibrate_constants(results)
