"""Apply an ExpertPlacement to live params / optimizer state.

A migration is a pure permutation of the expert dimension: physical slot
``p`` holds logical expert ``plan.physical_to_logical[p]``.  The router is
*not* rewritten — the plan's ``logical_to_physical`` index table remaps the
gate's expert ids at dispatch time (core/fmoe.py), so routing semantics (and
checkpoints, which store logical order via :func:`to_logical`) are unchanged.

Works on a single MoE layer's ``params["experts"]`` dict, on full LM trees
(stacked ``(L, E, ...)`` expert leaves are permuted on dim 1), and on AdamW
state (whose mu/nu mirror the param tree).

:class:`~repro.placement.plan.PerLayerPlacement` plans permute each layer's
slice of a stacked leaf with that layer's own table (``(L, E)`` index array,
``take_along_axis`` on dim 1); they require stacked trees — a per-layer plan
meeting a bare ``(E, ...)`` leaf is an error, not a silent broadcast.
"""
from __future__ import annotations

from typing import Any, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.placement.plan import ExpertPlacement, PerLayerPlacement

Plan = Union[ExpertPlacement, PerLayerPlacement]


def _expert_axis(path: tuple, shape: tuple, num_experts: int) -> int | None:
    """Axis of the expert dim for a leaf under an ``experts`` subtree.

    Per-layer expert params are ``(E, ...)``; LM trees stack layers in front
    (``(L, E, ...)``, see launch/sharding.py), so prefer axis 1 when both
    leading dims equal E (L == E ambiguity).
    """
    if not any("experts" in str(k) for k in path):
        return None
    if len(shape) >= 4 and shape[1] == num_experts:  # stacked (L, E, d, h)
        return 1
    if shape and shape[0] == num_experts:  # per-layer (E, d, h)
        return 0
    if len(shape) >= 2 and shape[1] == num_experts:
        return 1
    return None


def _permute_tree(tree: Any, idx: np.ndarray, num_experts: int) -> Any:
    """Permute expert leaves by ``idx``: (E,) shared or (L, E) per layer."""
    take = jnp.asarray(idx, jnp.int32)
    per_layer = take.ndim == 2

    def leaf(path, x):
        ax = _expert_axis(path, x.shape, num_experts)
        if ax is None:
            return x
        if not per_layer:
            return jnp.take(x, take, axis=ax)
        if ax != 1 or x.shape[0] != take.shape[0]:
            raise ValueError(
                f"per-layer plan ({take.shape[0]} layers) needs stacked "
                f"(L, E, ...) expert leaves; got {x.shape} at {path}")
        return jax.vmap(lambda xl, il: jnp.take(xl, il, axis=0))(x, take)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def _tables(plan: Plan, to_physical: bool) -> np.ndarray:
    """Index table(s) of a plan: (E,) for shared, (L, E) for per-layer."""
    if isinstance(plan, PerLayerPlacement):
        return (plan.physical_to_logical if to_physical
                else plan.logical_to_physical)
    if to_physical:
        return np.asarray(plan.physical_to_logical, np.int32)
    return plan.logical_to_physical


def migrate(tree: Any, old: Plan, new: Plan) -> Any:
    """Re-layout a tree from ``old``'s physical order into ``new``'s.

    ``tree`` may be a layer's params, a full LM param tree, or optimizer
    state — any pytree whose expert leaves live under an ``experts`` key.
    new_phys[p] = old_phys[old.l2p[new.p2l[p]]].  Shared and per-layer plans
    mix freely (a shared plan broadcasts over layers).
    """
    if old.num_experts != new.num_experts:
        raise ValueError((old.num_experts, new.num_experts))
    l2p_old = _tables(old, to_physical=False)
    p2l_new = _tables(new, to_physical=True)
    if l2p_old.ndim != p2l_new.ndim:  # mixed shared / per-layer: broadcast
        L = max(a.shape[0] for a in (l2p_old, p2l_new) if a.ndim == 2)
        if l2p_old.ndim == 1:
            l2p_old = np.broadcast_to(l2p_old, (L,) + l2p_old.shape)
        else:
            p2l_new = np.broadcast_to(p2l_new, (L,) + p2l_new.shape)
    idx = np.take_along_axis(l2p_old, p2l_new.astype(np.int32),
                             axis=-1) if l2p_old.ndim == 2 else \
        l2p_old[p2l_new.astype(np.int32)]
    return _permute_tree(tree, idx, new.num_experts)


def to_logical(tree: Any, plan: Plan) -> Any:
    """Physical -> logical order (the checkpoint-compatible layout)."""
    return _permute_tree(tree, _tables(plan, to_physical=False),
                         plan.num_experts)


def from_logical(tree: Any, plan: Plan) -> Any:
    """Logical -> physical order (what the executing layer consumes)."""
    return _permute_tree(tree, _tables(plan, to_physical=True),
                         plan.num_experts)


def router_index_table(plan: Plan) -> np.ndarray:
    """The logical->physical table(s) the gate output is mapped through:
    (E,) for a shared plan, (L, E) stacked for a per-layer plan."""
    return _tables(plan, to_physical=False)
