"""Apply an ExpertPlacement to live params / optimizer state.

A migration is a pure permutation of the expert dimension: physical slot
``p`` holds logical expert ``plan.physical_to_logical[p]``.  The router is
*not* rewritten — the plan's ``logical_to_physical`` index table remaps the
gate's expert ids at dispatch time (core/fmoe.py), so routing semantics (and
checkpoints, which store logical order via :func:`to_logical`) are unchanged.

Works on a single MoE layer's ``params["experts"]`` dict, on full LM trees
(stacked ``(L, E, ...)`` expert leaves are permuted on dim 1), and on AdamW
state (whose mu/nu mirror the param tree).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.placement.plan import ExpertPlacement


def _expert_axis(path: tuple, shape: tuple, num_experts: int) -> int | None:
    """Axis of the expert dim for a leaf under an ``experts`` subtree.

    Per-layer expert params are ``(E, ...)``; LM trees stack layers in front
    (``(L, E, ...)``, see launch/sharding.py), so prefer axis 1 when both
    leading dims equal E (L == E ambiguity).
    """
    if not any("experts" in str(k) for k in path):
        return None
    if len(shape) >= 4 and shape[1] == num_experts:  # stacked (L, E, d, h)
        return 1
    if shape and shape[0] == num_experts:  # per-layer (E, d, h)
        return 0
    if len(shape) >= 2 and shape[1] == num_experts:
        return 1
    return None


def _permute_tree(tree: Any, idx: np.ndarray, num_experts: int) -> Any:
    take = jnp.asarray(idx, jnp.int32)

    def leaf(path, x):
        ax = _expert_axis(path, x.shape, num_experts)
        if ax is None:
            return x
        return jnp.take(x, take, axis=ax)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def migrate(tree: Any, old: ExpertPlacement, new: ExpertPlacement) -> Any:
    """Re-layout a tree from ``old``'s physical order into ``new``'s.

    ``tree`` may be a layer's params, a full LM param tree, or optimizer
    state — any pytree whose expert leaves live under an ``experts`` key.
    new_phys[p] = old_phys[old.l2p[new.p2l[p]]].
    """
    if old.num_experts != new.num_experts:
        raise ValueError((old.num_experts, new.num_experts))
    idx = old.logical_to_physical[np.asarray(new.physical_to_logical,
                                             np.int32)]
    return _permute_tree(tree, idx, new.num_experts)


def to_logical(tree: Any, plan: ExpertPlacement) -> Any:
    """Physical -> logical order (the checkpoint-compatible layout)."""
    return _permute_tree(tree, plan.logical_to_physical, plan.num_experts)


def from_logical(tree: Any, plan: ExpertPlacement) -> Any:
    """Logical -> physical order (what the executing layer consumes)."""
    return _permute_tree(tree, np.asarray(plan.physical_to_logical, np.int32),
                         plan.num_experts)


def router_index_table(plan: ExpertPlacement) -> np.ndarray:
    """The logical->physical table the gate output is mapped through."""
    return plan.logical_to_physical
