"""The paper's primary contribution: the FastMoE system in JAX.

gate      — top-k / noisy-topk / expert-choice gating (§2.1, §3.1)
dispatch  — scatter/gather token reordering, capacity + ragged (§4, Fig 4)
fmoe      — the FMoE layer; local + distributed (a2a / psum) paths (§3)
comm      — collective helpers incl. hierarchical a2a (§3.2, Fig 2)
sync      — world/dp/none gradient-sync tags as sharding rules (§3.2)
balance   — load-balance losses + metrics (§6 future work)
monitor   — host-side load monitor + expert placement (§6 future work)
fmoefy    — the Megatron-plugin config rewrite (Listing 1)
naive     — the Rau-2019-style baselines the paper beats (§5.2)
"""
from repro.core.balance import MoEMetrics  # noqa: F401
from repro.core.fmoe import DistConfig, dense_ffn, expert_ffn, fmoe_apply, fmoe_init  # noqa: F401
from repro.core.fmoefy import fmoefy  # noqa: F401
from repro.core.gate import GateOutput, gate_forward, gate_init  # noqa: F401
