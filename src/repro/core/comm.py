"""Collective helpers for expert parallelism (paper §3.2 "global data
exchange") + beyond-paper hierarchical variants for the multi-pod mesh."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def exchange_counts(counts: jax.Array, axis: str) -> jax.Array:
    """Fig 2 step 1: exchange per-expert token counts over the expert axis.

    counts: (E,) local assignment counts, E = mp * E_local.
    returns (mp, E_local): incoming token counts per source rank.
    """
    mp = compat.axis_size(axis)
    return jax.lax.all_to_all(counts.reshape(mp, -1), axis, 0, 0, tiled=True)


def exchange_tokens(buf: jax.Array, axis: str) -> jax.Array:
    """Fig 2 step 2: payload all-to-all.  buf (E, C, d) -> (E_local, mp*C, d)."""
    mp = compat.axis_size(axis)
    E, C, d = buf.shape
    buf = buf.reshape(mp, E // mp, C, d)
    buf = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
    return buf.transpose(1, 0, 2, 3).reshape(E // mp, mp * C, d)


def return_tokens(out: jax.Array, axis: str) -> jax.Array:
    """Inverse of :func:`exchange_tokens`: (E_local, mp*C, d) -> (E, C, d)."""
    mp = compat.axis_size(axis)
    E_local, n, d = out.shape
    C = n // mp
    out = out.reshape(E_local, mp, C, d).transpose(1, 0, 2, 3)
    out = jax.lax.all_to_all(out, axis, 0, 0, tiled=True)
    return out.reshape(E_local * mp, C, d)


def exchange_ragged(send: jax.Array, counts: jax.Array, axis, mp: int, *,
                    n_chunks: int = 1, wire_dtype=None, fill_fn=None):
    """Ragged (dropless) global data exchange, forward direction.

    send: (mp, bound, d) pad-to-max-per-peer shards; counts: (mp, E_local)
    kept rows per (destination rank, its expert) — the explicit valid
    lengths of the variable-size exchange.  Returns ``(recv, incoming,
    fill_out)``: the received shards, the counts arriving from each source
    rank (which size the receiver's compaction — core/dispatch
    ragged_recv_compact), and the optional shadow-filler output.

    With ``n_chunks > 1`` both the counts and payload exchanges are
    ppermute-decomposed (no blocking all-to-all in the HLO at all).
    """
    from repro.core import pipeline

    incoming = pipeline.counts_all_to_all(counts, axis, mp,
                                          decompose=n_chunks > 1)
    recv, fill_out = pipeline.ragged_pipelined_exchange(
        send, axis, mp, n_chunks, fill_fn=fill_fn, wire_dtype=wire_dtype)
    return recv, incoming, fill_out


def return_ragged(out: jax.Array, axis, mp: int, *, n_chunks: int = 1,
                  wire_dtype=None) -> jax.Array:
    """Inverse of :func:`exchange_ragged`'s payload leg: (mp, bound, d_out)
    expert outputs travel back to their source ranks, landing in the same
    slots the sources sent from (the tiled a2a is its own inverse)."""
    from repro.core import pipeline

    return pipeline.chunked_all_to_all(out, axis, mp, n_chunks,
                                       wire_dtype=wire_dtype,
                                       decompose=n_chunks > 1)


def exchange_ragged_intra(send: jax.Array, counts: jax.Array, inner_axis,
                          n_inner: int, *, decompose: bool = False,
                          wire_dtype=None):
    """Hop 1 of the two-level ragged exchange: aggregate within the node.

    send: (n_nodes, n_inner, bound, d) per-peer shards, peers node-major
    (rank = node * n_inner + inner); counts: (n_nodes, n_inner, E_local) the
    matching kept-row counts.  Both run a dim-1 all-to-all over the fast
    node-local axis, after which this rank is its node's *forwarding agent*
    for its own inner slot: entry ``[o, s]`` is sibling ``s``'s shard (and
    counts) destined for rank ``(o, my_inner)`` of every node ``o`` — ready
    for the node-level compaction (core/dispatch.make_hier_agg) that strips
    per-source padding off the slow inter-node leg.
    """
    from repro.core import pipeline

    shards = pipeline.all_to_all_dim1(send, inner_axis, n_inner,
                                      decompose=decompose,
                                      wire_dtype=wire_dtype)
    cnt = pipeline.all_to_all_dim1(counts, inner_axis, n_inner,
                                   decompose=decompose)
    return shards, cnt


def return_ragged_intra(out: jax.Array, inner_axis, n_inner: int, *,
                        decompose: bool = False, wire_dtype=None) -> jax.Array:
    """Inverse of :func:`exchange_ragged_intra`'s payload hop: de-aggregated
    (n_nodes, n_inner, bound, d_out) outputs travel back to their source
    siblings (the dim-1 tiled a2a is its own inverse)."""
    from repro.core import pipeline

    return pipeline.all_to_all_dim1(out, inner_axis, n_inner,
                                    decompose=decompose, wire_dtype=wire_dtype)


def exchange_ragged_inter(slim: jax.Array, kept_counts: jax.Array, node_axis,
                          n_nodes: int, *, n_chunks: int = 1, wire_dtype=None,
                          fill_fn=None):
    """Hop 2 of the two-level ragged exchange: the slim inter-node leg.

    slim: (n_nodes, inter_bound, d) aggregated per-node shards (only
    truly-needed rows + tail padding); kept_counts: (n_nodes, n_inner,
    E_local) full per-source-rank granularity, so the receiver can rebuild
    the exact flat-path compaction.  When the installed jax has the native
    ``lax.ragged_all_to_all`` and the leg is not ppermute-decomposed, the
    payload travels through it (only valid prefixes cross the wire);
    otherwise the bounded-shard exchange moves the static buffer.  Returns
    ``(recv, incoming, fill_out)`` like :func:`exchange_ragged`.
    """
    from repro.core import pipeline

    incoming = pipeline.counts_all_to_all(
        kept_counts.reshape(n_nodes, -1), node_axis, n_nodes,
        decompose=n_chunks > 1).reshape(kept_counts.shape)
    if n_chunks <= 1 and compat.has_ragged_all_to_all():
        orig = slim.dtype
        w, wd = pipeline._to_wire(slim, orig, wire_dtype)
        recv = pipeline._from_wire(
            compat.ragged_all_to_all_shards(
                w, kept_counts.sum(axis=(1, 2)), incoming.sum(axis=(1, 2)),
                node_axis), orig, wd)
        return recv, incoming, (fill_fn() if fill_fn is not None else None)
    recv, fill_out = pipeline.ragged_pipelined_exchange(
        slim, node_axis, n_nodes, n_chunks, fill_fn=fill_fn,
        wire_dtype=wire_dtype)
    return recv, incoming, fill_out


def return_ragged_inter(out: jax.Array, kept_counts: jax.Array,
                        incoming: jax.Array, node_axis, n_nodes: int, *,
                        n_chunks: int = 1, wire_dtype=None) -> jax.Array:
    """Inverse of :func:`exchange_ragged_inter`'s payload leg (sizes swap
    roles: each rank returns what it received, gets back what it sent)."""
    from repro.core import pipeline

    if n_chunks <= 1 and compat.has_ragged_all_to_all():
        orig = out.dtype
        w, wd = pipeline._to_wire(out, orig, wire_dtype)
        return pipeline._from_wire(
            compat.ragged_all_to_all_shards(
                w, incoming.sum(axis=(1, 2)), kept_counts.sum(axis=(1, 2)),
                node_axis), orig, wd)
    return pipeline.chunked_all_to_all(out, node_axis, n_nodes, n_chunks,
                                       wire_dtype=wire_dtype,
                                       decompose=n_chunks > 1)


def hierarchical_all_to_all(buf: jax.Array, inner_axis: str,
                            outer_axis: str) -> jax.Array:
    """Beyond-paper: 2-hop all-to-all for multi-pod meshes.

    Cross-pod ICI/DCN links are far slower than intra-pod links, so exchange
    pod-locally first (aggregating messages destined for the same remote pod)
    and then do one large cross-pod exchange: (outer, inner, ...) layout.

    buf: (n_outer, n_inner, chunk...) — dim0 indexes destination outer rank,
    dim1 destination inner rank.
    """
    # hop 1: intra-pod exchange over the inner axis (fast links) so each inner
    # rank holds the traffic of its whole pod destined for one inner-peer slot
    buf = jax.lax.all_to_all(buf, inner_axis, 1, 1, tiled=True)
    # hop 2: cross-pod exchange over the outer (slow) axis, fully aggregated
    buf = jax.lax.all_to_all(buf, outer_axis, 0, 0, tiled=True)
    return buf


def all_to_all_bf16(buf: jax.Array, axis: str, split_axis: int = 0,
                    concat_axis: int = 0) -> jax.Array:
    """Beyond-paper: cast payload to bf16 across the wire (halves collective
    bytes; combine-weight math stays f32)."""
    orig = buf.dtype
    out = jax.lax.all_to_all(buf.astype(jnp.bfloat16), axis, split_axis,
                             concat_axis, tiled=True)
    return out.astype(orig)
