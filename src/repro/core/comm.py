"""Collective helpers for expert parallelism (paper §3.2 "global data
exchange") + beyond-paper hierarchical variants for the multi-pod mesh."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def exchange_counts(counts: jax.Array, axis: str) -> jax.Array:
    """Fig 2 step 1: exchange per-expert token counts over the expert axis.

    counts: (E,) local assignment counts, E = mp * E_local.
    returns (mp, E_local): incoming token counts per source rank.
    """
    mp = compat.axis_size(axis)
    return jax.lax.all_to_all(counts.reshape(mp, -1), axis, 0, 0, tiled=True)


def exchange_tokens(buf: jax.Array, axis: str) -> jax.Array:
    """Fig 2 step 2: payload all-to-all.  buf (E, C, d) -> (E_local, mp*C, d)."""
    mp = compat.axis_size(axis)
    E, C, d = buf.shape
    buf = buf.reshape(mp, E // mp, C, d)
    buf = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
    return buf.transpose(1, 0, 2, 3).reshape(E // mp, mp * C, d)


def return_tokens(out: jax.Array, axis: str) -> jax.Array:
    """Inverse of :func:`exchange_tokens`: (E_local, mp*C, d) -> (E, C, d)."""
    mp = compat.axis_size(axis)
    E_local, n, d = out.shape
    C = n // mp
    out = out.reshape(E_local, mp, C, d).transpose(1, 0, 2, 3)
    out = jax.lax.all_to_all(out, axis, 0, 0, tiled=True)
    return out.reshape(E_local * mp, C, d)


def exchange_ragged(send: jax.Array, counts: jax.Array, axis, mp: int, *,
                    n_chunks: int = 1, wire_dtype=None, fill_fn=None):
    """Ragged (dropless) global data exchange, forward direction.

    send: (mp, bound, d) pad-to-max-per-peer shards; counts: (mp, E_local)
    kept rows per (destination rank, its expert) — the explicit valid
    lengths of the variable-size exchange.  Returns ``(recv, incoming,
    fill_out)``: the received shards, the counts arriving from each source
    rank (which size the receiver's compaction — core/dispatch
    ragged_recv_compact), and the optional shadow-filler output.

    With ``n_chunks > 1`` both the counts and payload exchanges are
    ppermute-decomposed (no blocking all-to-all in the HLO at all).
    """
    from repro.core import pipeline

    incoming = pipeline.counts_all_to_all(counts, axis, mp,
                                          decompose=n_chunks > 1)
    recv, fill_out = pipeline.ragged_pipelined_exchange(
        send, axis, mp, n_chunks, fill_fn=fill_fn, wire_dtype=wire_dtype)
    return recv, incoming, fill_out


def return_ragged(out: jax.Array, axis, mp: int, *, n_chunks: int = 1,
                  wire_dtype=None) -> jax.Array:
    """Inverse of :func:`exchange_ragged`'s payload leg: (mp, bound, d_out)
    expert outputs travel back to their source ranks, landing in the same
    slots the sources sent from (the tiled a2a is its own inverse)."""
    from repro.core import pipeline

    return pipeline.chunked_all_to_all(out, axis, mp, n_chunks,
                                       wire_dtype=wire_dtype,
                                       decompose=n_chunks > 1)


def hierarchical_all_to_all(buf: jax.Array, inner_axis: str,
                            outer_axis: str) -> jax.Array:
    """Beyond-paper: 2-hop all-to-all for multi-pod meshes.

    Cross-pod ICI/DCN links are far slower than intra-pod links, so exchange
    pod-locally first (aggregating messages destined for the same remote pod)
    and then do one large cross-pod exchange: (outer, inner, ...) layout.

    buf: (n_outer, n_inner, chunk...) — dim0 indexes destination outer rank,
    dim1 destination inner rank.
    """
    # hop 1: intra-pod exchange over the inner axis (fast links) so each inner
    # rank holds the traffic of its whole pod destined for one inner-peer slot
    buf = jax.lax.all_to_all(buf, inner_axis, 1, 1, tiled=True)
    # hop 2: cross-pod exchange over the outer (slow) axis, fully aggregated
    buf = jax.lax.all_to_all(buf, outer_axis, 0, 0, tiled=True)
    return buf


def all_to_all_bf16(buf: jax.Array, axis: str, split_axis: int = 0,
                    concat_axis: int = 0) -> jax.Array:
    """Beyond-paper: cast payload to bf16 across the wire (halves collective
    bytes; combine-weight math stays f32)."""
    orig = buf.dtype
    out = jax.lax.all_to_all(buf.astype(jnp.bfloat16), axis, split_axis,
                             concat_axis, tiled=True)
    return out.astype(orig)
