"""Token scatter/gather — the paper's §4 reordered computation (Fig 4).

FastMoE's core single-device insight: batch all tokens routed to the same
expert contiguously (**scatter**), run one big GeMM per expert, then put
outputs back in original order (**gather**).

Two TPU-native realizations (DESIGN.md §2):

* ``capacity`` — GShard-style static buffers ``(E, C, d)``.  XLA needs static
  shapes, so FastMoE's runtime-sized recv buffers become a fixed per-expert
  capacity; overflow tokens are dropped (tracked).  This is the mode that
  composes with expert-parallel all-to-all.
* ``ragged`` — expert-sorted token array + group sizes, no drops; feeds the
  Pallas grouped-GEMM kernel.  Static total size (T*k), ragged within.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float, *, multiple: int = 8) -> int:
    """Static per-expert buffer length C."""
    c = math.ceil(num_tokens * top_k * capacity_factor / num_experts)
    return max(multiple, math.ceil(c / multiple) * multiple)


# ---------------------------------------------------------------------------
# Capacity (static-buffer) dispatch
# ---------------------------------------------------------------------------


class CapacityPlan(NamedTuple):
    """Routing of each (token, slot) pair into the (E, C) buffer grid."""

    expert_ids: jax.Array  # (T, k) int32
    positions: jax.Array  # (T, k) int32 — row within the expert buffer; ==C if dropped
    keep: jax.Array  # (T, k) bool
    load: jax.Array  # (E,) int32 — tokens *assigned* per expert (pre-drop)
    capacity: int


def make_capacity_plan(expert_ids: jax.Array, num_experts: int,
                       capacity) -> CapacityPlan:
    """Assign buffer positions with slot-major priority (top-1 choices first),
    matching GShard so lower-k choices survive overflow.

    ``capacity`` may be a single int or a static per-expert sequence (the
    placement subsystem shrinks the a2a experts' buffers independently of the
    shadowed ones); ``plan.capacity`` is the buffer width = max over experts,
    and dropped rows get position == width so scatter/gather skip them.
    """
    import numpy as np
    T, k = expert_ids.shape
    if isinstance(capacity, (int, np.integer)):
        caps, width = None, int(capacity)
    else:
        caps_np = np.asarray(capacity, np.int32)
        assert caps_np.shape == (num_experts,), caps_np.shape
        caps, width = jnp.asarray(caps_np), int(caps_np.max())
    # slot-major flatten: all slot-0 assignments precede slot-1, etc.
    flat = expert_ids.T.reshape(-1)  # (k*T,)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # (kT, E)
    # 0-indexed position of each row within its expert's arrival order
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_expert, flat[:, None], axis=1)[:, 0]
    keep = pos < (width if caps is None else caps[flat])
    pos = jnp.where(keep, pos, width)  # out-of-range rows are dropped by scatter
    load = onehot.sum(axis=0)
    # back to token-major (T, k)
    unflatten = lambda a: a.reshape(k, T).T
    return CapacityPlan(expert_ids, unflatten(pos), unflatten(keep), load, width)


def dispatch_capacity(x: jax.Array, plan: CapacityPlan,
                      num_experts: int) -> jax.Array:
    """Scatter tokens (T, d) into per-expert buffers (E, C, d)."""
    T, d = x.shape
    k = plan.expert_ids.shape[1]
    buf = jnp.zeros((num_experts, plan.capacity, d), x.dtype)
    eid = plan.expert_ids.reshape(-1)
    pos = plan.positions.reshape(-1)
    rows = jnp.repeat(jnp.arange(T), k)  # token index per (token, slot)
    # out-of-bounds pos==C rows are dropped (jnp scatter drop semantics)
    return buf.at[eid, pos].set(x[rows], mode="drop")


def combine_capacity(out_buf: jax.Array, plan: CapacityPlan,
                     combine_weights: jax.Array) -> jax.Array:
    """Gather expert outputs (E, C, dout) back to token order, weighted-sum over k."""
    T, k = plan.expert_ids.shape
    eid = plan.expert_ids.reshape(-1)
    pos = plan.positions.reshape(-1)
    gathered = out_buf.at[eid, pos].get(mode="fill", fill_value=0)  # (T*k, dout)
    gathered = gathered.reshape(T, k, -1)
    w = (combine_weights * plan.keep).astype(gathered.dtype)
    return jnp.einsum("tk,tkd->td", w, gathered)


def combine_capacity_slots(out_buf: jax.Array, plan: CapacityPlan,
                           combine_weights: jax.Array) -> jax.Array:
    """Per-slot weighted expert outputs (T, k, dout) — no k-reduction.

    The psum (decode) path reduces these across ranks *before* summing the k
    slots in fixed order, which makes the result bitwise-invariant to which
    rank serves which slot: a per-rank k-sum (combine_capacity's einsum) may
    FMA-fuse a token's co-located slot pair into one rounding, so permuting
    or shadowing experts would shift results by an ulp.  Here every slot
    contribution is rounded exactly once (the product), on whichever rank
    computes it, and the cross-slot sum happens identically everywhere.
    """
    T, k = plan.expert_ids.shape
    eid = plan.expert_ids.reshape(-1)
    pos = plan.positions.reshape(-1)
    gathered = out_buf.at[eid, pos].get(mode="fill", fill_value=0)
    gathered = gathered.reshape(T, k, -1)
    w = (combine_weights * plan.keep).astype(gathered.dtype)
    return w[:, :, None] * gathered


# ---------------------------------------------------------------------------
# Ragged (sorted) dispatch — FastMoE-faithful, no drops
# ---------------------------------------------------------------------------


class RaggedPlan(NamedTuple):
    sort_idx: jax.Array  # (T*k,) int32 — argsort of flat expert ids
    group_sizes: jax.Array  # (E,) int32
    token_rows: jax.Array  # (T*k,) int32 — source token per sorted row


def make_ragged_plan(expert_ids: jax.Array, num_experts: int) -> RaggedPlan:
    T, k = expert_ids.shape
    flat = expert_ids.reshape(-1)  # token-major
    sort_idx = jnp.argsort(flat, stable=True).astype(jnp.int32)
    group_sizes = jnp.bincount(flat, length=num_experts).astype(jnp.int32)
    token_rows = (sort_idx // k).astype(jnp.int32)
    return RaggedPlan(sort_idx, group_sizes, token_rows)


def dispatch_ragged(x: jax.Array, plan: RaggedPlan) -> jax.Array:
    """Gather tokens (T, d) into expert-sorted order (T*k, d)."""
    return x[plan.token_rows]


def combine_ragged(y_sorted: jax.Array, plan: RaggedPlan,
                   combine_weights: jax.Array) -> jax.Array:
    """Un-sort expert outputs (T*k, dout) and weighted-sum the k slots."""
    T, k = combine_weights.shape
    y_flat = jnp.zeros_like(y_sorted).at[plan.sort_idx].set(y_sorted)
    y = y_flat.reshape(T, k, -1)
    return jnp.einsum("tk,tkd->td", combine_weights.astype(y.dtype), y)


def combine_ragged_slots(y_sorted: jax.Array, plan: RaggedPlan,
                         combine_weights: jax.Array) -> jax.Array:
    """Ragged analogue of :func:`combine_capacity_slots`: un-sorted per-slot
    weighted outputs (T, k, dout), k-reduction left to the caller (the psum
    decode path sums slots after the cross-rank reduction, in fixed order)."""
    T, k = combine_weights.shape
    y_flat = jnp.zeros_like(y_sorted).at[plan.sort_idx].set(y_sorted)
    y = y_flat.reshape(T, k, -1)
    return combine_weights[:, :, None].astype(y.dtype) * y


# ---------------------------------------------------------------------------
# Expert-choice dispatch — exact capacities by construction (Zhou et al. 2022)
# ---------------------------------------------------------------------------
#
# Expert-choice inverts the selection: each expert picks its top-C tokens, so
# every expert buffer is exactly C rows — no capacity padding waste, no drops,
# flat load.  Dispatch is a plain gather ``x[token_idx]`` into the same
# (E, C, d) grid the capacity machinery exchanges, and the ragged layout is
# the degenerate uniform case ``group_sizes == C`` — both exchange paths get
# a second client without new plumbing.


def ec_capacity(num_tokens: int, num_experts: int,
                capacity_factor: float) -> int:
    """Per-expert row count for expert-choice routing.

    Must match ``gate.expert_choice_moe``'s dense reference exactly — the
    dispatched paths are differentially tested against it.  Clamped to the
    token count: an expert can't pick more tokens than exist (top-C over T
    rows requires C <= T), and beyond that every expert already takes
    everything.
    """
    return max(1, min(num_tokens,
                      int(num_tokens * capacity_factor / num_experts)))


def combine_ec(out: jax.Array, token_idx: jax.Array, weights: jax.Array,
               num_tokens: int) -> jax.Array:
    """Scatter-add weighted expert outputs back to token order.

    ``out`` (E, C, dout) must be in LOGICAL expert order (callers gather
    physically-placed outputs through the plan's table first) so the
    scatter-add ordering — and therefore the f32 rounding — is invariant to
    the expert layout, matching the dense reference bitwise.
    """
    E, C, dout = out.shape
    y = jnp.zeros((num_tokens, dout), out.dtype)
    return y.at[token_idx.reshape(-1)].add(
        (out * weights[..., None].astype(out.dtype)).reshape(E * C, dout))


def ec_to_physical(token_idx: jax.Array, table: jax.Array | None) -> jax.Array:
    """Permute the (E, C) expert-choice token grid from logical to physical
    expert order (row e of the result belongs to physical slot e).  Uniform
    capacities make this a pure row permutation — group sizes are unchanged.
    ``table`` is the logical->physical id table (None = identity)."""
    if table is None:
        return token_idx
    return jnp.zeros_like(token_idx).at[table].set(token_idx)


# ---------------------------------------------------------------------------
# Cross-rank ragged plans — the distributed dropless exchange (ISSUE 4)
# ---------------------------------------------------------------------------
#
# The capacity a2a pads every expert buffer to C rows before the wire; the
# ragged exchange instead moves the *locally sorted* rows in per-peer shards:
# each rank's rows destined for peer p form one contiguous segment of its
# expert-sorted array (experts are contiguous per rank), scattered into shard
# p of a (mp, bound, d) send buffer.  ``bound`` is the static pad-to-max-
# per-peer width that keeps the exchange jit-able; the *valid lengths* ride
# separately as the (mp, E_local) counts all-to-all, so the receiver can
# compact the padded shards back into a load-sized expert-sorted array for
# the grouped kernels (RAGGED_FNS).  bound = T*k is provably dropless.


class RaggedXPlan(NamedTuple):
    """Send-side geometry of the ragged (dropless) all-to-all.

    Indexes the rank's expert-sorted rows (make_ragged_plan order): physical
    experts [0, num_owned) are exchanged, any shadowed tail is served
    locally (repro/placement/shadow.py contract).
    """

    send_dest: jax.Array  # (T*k,) int32 — slot in the flat (mp*bound) send
    # buffer; == mp*bound for rows not exchanged (shadowed / over-bound)
    peer_counts: jax.Array  # (mp, E_local) int32 — rows that FIT the bound,
    # per (destination rank, its local expert); the counts-a2a payload
    keep: jax.Array  # (T*k,) bool — owned rows that fit the per-peer bound
    num_owned_rows: jax.Array  # () int32 — rows routed to owned experts


def make_ragged_xplan(group_sizes: jax.Array, num_rows: int, num_owned: int,
                      num_peers: int, bound: int) -> RaggedXPlan:
    """Lay this rank's ``num_rows`` sorted rows into per-peer shards of width
    ``bound``.

    group_sizes: (E,) of the local expert sort (physical order).  The first
    ``num_owned`` experts live on the a2a (``num_owned // num_peers`` per
    peer, contiguous per-rank blocks); the rest are shadowed.  Rows of one
    peer keep their expert-sorted order inside the shard, so the receiver
    can reconstruct expert segments from the exchanged counts alone.
    """
    e_pp = num_owned // num_peers
    raw = group_sizes[:num_owned].reshape(num_peers, e_pp)
    peer_tot = raw.sum(axis=1)
    cum = jnp.cumsum(peer_tot)  # (mp,) inclusive
    num_owned_rows = cum[-1]
    i = jnp.arange(num_rows, dtype=jnp.int32)
    owned = i < num_owned_rows
    peer = jnp.clip(jnp.searchsorted(cum, i, side="right"),
                    0, num_peers - 1).astype(jnp.int32)
    within = i - (cum[peer] - peer_tot[peer])  # position inside the shard
    keep = owned & (within < bound)
    send_dest = jnp.where(keep, peer * bound + within,
                          num_peers * bound).astype(jnp.int32)
    # kept rows per (peer, expert): experts fill the shard in order, so the
    # bound truncates the trailing experts of an over-full shard
    off_in_peer = jnp.cumsum(raw, axis=1) - raw  # exclusive, per peer
    peer_counts = jnp.clip(bound - off_in_peer, 0, raw).astype(jnp.int32)
    return RaggedXPlan(send_dest, peer_counts, keep, num_owned_rows)


def ragged_recv_compact(incoming: jax.Array, bound: int):
    """Compaction map for the received (mp, bound, d) shards.

    incoming: (mp, E_local) kept-row counts from each source rank (the
    counts-a2a output).  Shard s holds ``incoming[s].sum()`` valid rows,
    expert-sorted with segment lengths ``incoming[s]``.  Returns
    ``(dest, group_sizes)``: ``dest`` (mp*bound,) maps each received slot to
    its row in the expert-sorted compact array (== mp*bound for padding →
    scatter-drop / gather-fill), and ``group_sizes`` (E_local,) are the
    compact array's per-expert segment lengths, src-major within an expert —
    i.e. global token order when ranks hold contiguous token blocks.
    """
    mp, e_local = incoming.shape
    gs = incoming.sum(axis=0)  # (E_local,)
    e_off = jnp.cumsum(gs) - gs  # exclusive expert offsets in compact array
    prior = jnp.cumsum(incoming, axis=0) - incoming  # earlier-src rows per e
    in_off = jnp.cumsum(incoming, axis=1) - incoming  # within-src expert offs
    cum_src = jnp.cumsum(incoming, axis=1)  # (mp, E_local) inclusive
    src_tot = incoming.sum(axis=1)  # (mp,)
    idx = jnp.arange(mp * bound, dtype=jnp.int32)
    s, j = idx // bound, idx % bound
    # expert of slot (s, j): how many inclusive-cumsum boundaries j passed
    e = jnp.clip((j[:, None] >= cum_src[s]).sum(axis=1),
                 0, e_local - 1).astype(jnp.int32)
    valid = j < src_tot[s]
    dest = e_off[e] + prior[s, e] + (j - in_off[s, e])
    return jnp.where(valid, dest, mp * bound).astype(jnp.int32), gs


# ---------------------------------------------------------------------------
# Two-level (hierarchical) ragged exchange — node-level aggregation (ISSUE 7)
# ---------------------------------------------------------------------------
#
# On a mesh with a node axis (DistConfig.node_axis), the flat per-peer shards
# first exchange within the node (fast links, dim-1 a2a): afterwards each
# rank is its node's *forwarding agent* for its own inner slot, holding every
# sibling's shard destined for rank (o, my_inner) of every node o.  The agent
# compacts the n_inner valid prefixes into ONE slim shard per destination
# node (width ``inter_bound``), so the slow inter-node exchange carries only
# truly-needed rows — per-source padding never crosses a node boundary, and
# an adaptive ``inter_bound`` (LoadMonitor-calibrated) shrinks the wire with
# actual load.  The receiver rebuilds the exact expert-sorted compact array
# of the flat path (source-rank-major within an expert), so the two paths
# are bit-exact when nothing drops.


class HierAggPlan(NamedTuple):
    """Forwarding-agent geometry: n_inner padded shards -> one slim shard."""

    agg_dest: jax.Array  # (n_nodes*n_inner*bound,) int32 — slot in the flat
    # (n_nodes*inter_bound) slim buffer; == n_nodes*inter_bound when invalid
    kept_counts: jax.Array  # (n_nodes, n_inner, E_local) int32 — rows that
    # fit the inter bound, per (dest node, source sibling, expert); the
    # inter-node counts-leg payload
    dropped: jax.Array  # () f32 — rows this agent dropped at the inter bound


def make_hier_agg(cnt_agg: jax.Array, bound: int,
                  inter_bound: int) -> HierAggPlan:
    """Compact per-sibling padded shards into slim per-node shards.

    cnt_agg: (n_nodes, n_inner, E_local) — after the intra counts hop, the
    kept-row counts of sibling ``s``'s shard for destination node ``o``
    (each shard a valid prefix of ``cnt_agg[o, s].sum()`` rows padded to
    ``bound``).  Sibling prefixes concatenate in sibling order inside the
    slim shard; ``inter_bound`` truncates the trailing rows of an over-full
    node shard (tracked in ``dropped`` — never silent).
    """
    n_nodes, n_inner, e_local = cnt_agg.shape
    seg = cnt_agg.sum(-1)  # (n_nodes, n_inner) valid prefix lengths
    off = jnp.cumsum(seg, axis=1) - seg  # sibling offsets in the slim shard
    idx = jnp.arange(n_nodes * n_inner * bound, dtype=jnp.int32)
    o = idx // (n_inner * bound)
    s = (idx // bound) % n_inner
    b = idx % bound
    pos = off[o, s] + b
    valid = (b < seg[o, s]) & (pos < inter_bound)
    agg_dest = jnp.where(valid, o * inter_bound + pos,
                         n_nodes * inter_bound).astype(jnp.int32)
    # kept rows per (o, s, e): experts fill each sibling run in order, so the
    # inter bound truncates trailing (sibling, expert) segments — the same
    # clip pattern as make_ragged_xplan's peer_counts
    e_off = off[..., None] + (jnp.cumsum(cnt_agg, axis=-1) - cnt_agg)
    kept = jnp.clip(inter_bound - e_off, 0, cnt_agg).astype(jnp.int32)
    dropped = (cnt_agg.sum() - kept.sum()).astype(jnp.float32)
    return HierAggPlan(agg_dest, kept, dropped)


def _hier_slots(incoming: jax.Array, inter_bound: int):
    """Per-slot structure of the received slim shards.

    incoming: (n_nodes, n_inner, E_local) kept counts from every source rank
    (node-major).  Shard ``i`` holds compacted sibling-major runs, each run
    expert-sorted with lengths ``incoming[i, s]``.  Returns per flat slot
    (n_nodes*inter_bound,): source sibling ``s``, within-sibling row ``r``,
    expert ``e``, and validity.
    """
    n_nodes, n_inner, e_local = incoming.shape
    seg = incoming.sum(-1)  # (n_nodes, n_inner)
    soff = jnp.cumsum(seg, axis=1) - seg
    cum_sib = jnp.cumsum(seg, axis=1)  # inclusive
    cum_e = jnp.cumsum(incoming, axis=-1)  # inclusive, within sibling
    idx = jnp.arange(n_nodes * inter_bound, dtype=jnp.int32)
    i, q = idx // inter_bound, idx % inter_bound
    s = jnp.clip((q[:, None] >= cum_sib[i]).sum(axis=1),
                 0, n_inner - 1).astype(jnp.int32)
    r = q - soff[i, s]
    e = jnp.clip((r[:, None] >= cum_e[i, s]).sum(axis=1),
                 0, e_local - 1).astype(jnp.int32)
    valid = q < cum_sib[i, n_inner - 1]
    return i, s, r, e, valid


def ragged_recv_compact_hier(incoming: jax.Array, inter_bound: int):
    """Two-level analogue of :func:`ragged_recv_compact`.

    Maps each received slim slot to its row in the SAME expert-sorted
    compact array the flat path builds (source-rank-major within an expert,
    ranks node-major) — the bit-exactness anchor of the hierarchical path.
    Returns ``(dest (n_nodes*inter_bound,), group_sizes (E_local,))``;
    invalid slots map to ``n_nodes*inter_bound``.
    """
    n_nodes, n_inner, e_local = incoming.shape
    flat_cnt = incoming.reshape(n_nodes * n_inner, e_local)  # src-rank major
    gs = flat_cnt.sum(axis=0)
    e_off = jnp.cumsum(gs) - gs
    prior = jnp.cumsum(flat_cnt, axis=0) - flat_cnt  # earlier-src rows per e
    in_off = jnp.cumsum(incoming, axis=-1) - incoming  # within-sib expert offs
    i, s, r, e, valid = _hier_slots(incoming, inter_bound)
    dest = e_off[e] + prior[i * n_inner + s, e] + (r - in_off[i, s, e])
    return (jnp.where(valid, dest, n_nodes * inter_bound).astype(jnp.int32),
            gs.astype(jnp.int32))


def hier_chunk_plans(incoming: jax.Array, inter_bound: int, n_chunks: int):
    """Per-chunk mini-compaction maps for per-received-chunk expert compute.

    Chunk ``c`` of the inter-node exchange delivers slots ``[c*w, (c+1)*w)``
    of every source node's slim shard (``w = inter_bound // n_chunks``).
    Each chunk's valid rows form their own expert-sorted mini array so the
    grouped kernels can run on chunk ``c`` while chunk ``c+1`` is still in
    flight — the per-chunk dynamic group slicing of the §5.2 follow-on.
    Returns ``(dest (n_chunks, n_nodes*w), gs (n_chunks, E_local))``; ``dest``
    maps chunk slots (node-major) into the mini array (invalid → n_nodes*w).
    """
    n_nodes, n_inner, e_local = incoming.shape
    w = inter_bound // n_chunks
    _, _, _, e, valid = _hier_slots(incoming, inter_bound)
    # regroup flat slots (i, q) -> (chunk c, node i, within-chunk q')
    e_c = e.reshape(n_nodes, n_chunks, w).transpose(1, 0, 2).reshape(
        n_chunks, n_nodes * w)
    v_c = valid.reshape(n_nodes, n_chunks, w).transpose(1, 0, 2).reshape(
        n_chunks, n_nodes * w)
    onehot = jax.nn.one_hot(e_c, e_local, dtype=jnp.int32) * v_c[..., None]
    gs = onehot.sum(axis=1)  # (n_chunks, E_local)
    g_off = jnp.cumsum(gs, axis=-1) - gs
    before = jnp.cumsum(onehot, axis=1) - onehot  # earlier chunk slots per e
    dest = (jnp.take_along_axis(g_off, e_c, axis=1)
            + jnp.take_along_axis(before, e_c[..., None], axis=2)[..., 0])
    dest = jnp.where(v_c, dest, n_nodes * w).astype(jnp.int32)
    return dest, gs.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Tile padding for the Pallas grouped GEMM (groups aligned to row tiles)
# ---------------------------------------------------------------------------


class TiledRagged(NamedTuple):
    x: jax.Array  # (P, d) — sorted rows scattered into tile-aligned slots
    row_valid: jax.Array  # (P,) bool
    tile_group: jax.Array  # (P // tile,) int32 — expert id owning each row tile
    dest: jax.Array  # (T*k,) int32 — tile-aligned slot of each sorted row
    padded_offsets: jax.Array  # (E,) int32 — start of each expert's padded block


def pad_to_tiles(x_sorted: jax.Array, group_sizes: jax.Array, tile: int,
                 num_experts: int) -> TiledRagged:
    """Re-lay sorted rows so every expert's block starts on a tile boundary.

    Static output size P = ceil(T*k/tile)*tile + E*tile upper bound (each group
    padded up to a tile multiple).
    """
    n = x_sorted.shape[0]
    padded_sizes = (group_sizes + tile - 1) // tile * tile
    padded_offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                      jnp.cumsum(padded_sizes)[:-1].astype(jnp.int32)])
    group_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                    jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    P = (n + tile - 1) // tile * tile + num_experts * tile  # static upper bound
    sorted_eid = jnp.repeat(jnp.arange(num_experts, dtype=jnp.int32),
                            group_sizes, total_repeat_length=n)
    within = jnp.arange(n, dtype=jnp.int32) - group_starts[sorted_eid]
    dest = padded_offsets[sorted_eid] + within
    x_p = jnp.zeros((P, x_sorted.shape[1]), x_sorted.dtype).at[dest].set(x_sorted)
    row_valid = jnp.zeros((P,), jnp.bool_).at[dest].set(True)
    # expert owning each tile: tiles within [padded_offsets[e], +padded_sizes[e])
    tile_starts = jnp.arange(P // tile, dtype=jnp.int32) * tile
    tile_group = jnp.clip(
        jnp.searchsorted(padded_offsets, tile_starts, side="right") - 1,
        0, num_experts - 1).astype(jnp.int32)
    return TiledRagged(x_p, row_valid, tile_group, dest.astype(jnp.int32),
                       padded_offsets)


def unpad_tiles(y_padded: jax.Array, tiled: TiledRagged) -> jax.Array:
    """Inverse of :func:`pad_to_tiles` row layout (back to sorted order)."""
    return y_padded[tiled.dest]
