"""The FMoE layer — paper §3 (system design) + §4 (reordered computation).

Functional analogue of FastMoE's ``FMoE`` / ``FMoETransformerMLP``:

* arbitrary expert networks via an overloadable ``expert_fn`` (paper §3.1);
* scatter → batched per-expert GeMM → gather reordering (paper §4, Fig 4);
* expert parallelism across workers with explicit all-to-all global data
  exchange (paper §3.2, Fig 2), realized as ``shard_map`` + ``lax.all_to_all``
  over the ``model`` mesh axis;
* a ``psum`` mode for decode-time shapes where tokens cannot be sharded
  across the expert axis (each rank computes its local experts for all its
  tokens, partial outputs are psum-combined);
* load-balance losses + monitoring (paper §6 future work, beyond-paper).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import MoEConfig
from repro.core import dispatch as D
from repro.core import pipeline
from repro.core.balance import MoEMetrics, load_balance_loss, load_metrics, router_z_loss
from repro.core.gate import (expert_choice_forward, gate_forward, gate_init,
                             route_tokens, router_distill_loss, router_init)
from repro.obs import counters as obs_counters
from repro.obs.counters import ObsCounters


class DistConfig(NamedTuple):
    """How the MoE layer is distributed over the device mesh.

    mode "a2a" (tokens sharded over the expert axis too -> all-to-all
    exchange, the paper's §3.2 pattern) is chosen automatically when
    ``expert_axis`` appears in ``token_axes``; otherwise "psum".

    Beyond-paper options (§Perf):
      tp_axis — expert-internal tensor parallelism: expert hidden dims stay
        sharded over this axis and activations psum, instead of FSDP
        all-gathering the expert weights every layer.
      constrain_tokens — pin the flat-token sharding for the shared/dense
        residual FFNs so XLA doesn't replicate the token array when leaving
        the shard_map region (fixes the SPMD "involuntary rematerialization").
      placement — an ExpertPlacement (repro.placement.plan): params are in
        its physical order, gate ids are remapped through its index table,
        and shadowed hot experts run replicated outside the all-to-all (a2a
        modes) or outside the psum reduction (decode mode).  At the model
        level this may be a PerLayerPlacement — models/lm.py splits it into
        the shared geometry (which rides here) plus per-layer gate-id
        tables threaded through the layer scan (fmoe_apply's ``l2p``).
      overlap_chunks — §5.2 smart schedule: split the a2a payload into this
        many capacity micro-shards and pipeline exchange with expert compute
        (repro.core.pipeline).  0/1 = serial; values that don't divide the
        capacity degrade to the nearest feasible depth.  Bit-exact vs serial.
      wire_dtype — cast a2a payloads to this dtype across the wire only
        ("bf16" halves exchange bytes; accumulation/combine stay f32).
      ragged_bound — rows per peer shard of the ragged (dropless) exchange
        (cfg.dispatch == "ragged" under a2a mode): the static pad-to-max-
        per-peer width that keeps the variable-size exchange jit-able.
        0 = T_local*k, which provably never drops; a smaller bound shrinks
        wire bytes toward actual load at the price of GShard-style drops
        when one peer's shard overflows (tracked in metrics.drop_frac).
      node_axis — hierarchical two-level ragged exchange: the name of the
        *inter-node* mesh axis (launch/mesh make_local_mesh(node=...)).
        When set and leading ``expert_axes`` (ranks node-major), the ragged
        a2a splits into an intra-node aggregation hop over the remaining
        (fast) expert axes and a slim inter-node hop over this (slow) axis
        that carries only truly-needed rows — per-source padding never
        crosses a node boundary.  Bit-exact vs. the flat exchange.  None, or
        a mesh without the axis, keeps the flat single-level exchange.
      inter_bound — rows per slim per-node shard of the inter-node hop
        (0 = n_inner * ragged_bound, which never drops at this stage); a
        smaller value shrinks inter-node wire bytes toward actual load, with
        overflow rows dropped by the forwarding agent (also in drop_frac).
        launch/train's ``ragged_bound=auto`` calibrates both bounds from the
        LoadMonitor's EMAs.
    """

    mesh: Any
    token_axes: tuple  # mesh axes sharding the flat token dim
    # single axis name, or a tuple of axes (e.g. ("pod", "model") for
    # cross-pod expert parallelism, §Perf multi-pod)
    expert_axis: Any = "model"
    tp_axis: Optional[str] = None
    constrain_tokens: bool = False
    fsdp_axis: Optional[str] = None  # constrain bf16-cast weights sharded
    # so the per-layer FSDP gather moves bf16, not the f32 master (§Perf)
    placement: Any = None  # Optional[repro.placement.plan.ExpertPlacement]
    overlap_chunks: int = 0  # §5.2 pipelined exchange (0/1 = serial)
    wire_dtype: Optional[str] = None  # a2a payload dtype ("bf16" | None)
    ragged_bound: int = 0  # dropless-exchange peer-shard rows (0 = T*k)
    # device-side telemetry counters (repro.obs.counters) riding the metrics
    # output.  They are derived from static shapes + values the paths already
    # reduce (no extra collectives — tests/test_obs.py locks the HLO diff);
    # False pins them to zeros, which is what that regression test compares
    # against.
    obs: bool = True
    node_axis: Optional[str] = None  # inter-node axis of the two-level
    # ragged exchange (must lead expert_axes); None = flat exchange
    inter_bound: int = 0  # slim inter-node shard rows (0 = n_inner * bound)
    router: Optional[str] = None  # override cfg.router for this distribution
    # (e.g. launch/serve pins the decode router without touching the model
    # config); None = use MoEConfig.router

    @classmethod
    def local(cls, placement=None) -> "DistConfig":
        """Single-worker carrier: no mesh, no collectives.  fmoe_apply routes
        a ``mesh=None`` dist to the local §4 path, so this is how a placement
        (index-table routing over physically reordered params) rides the one
        distribution-config channel without a device mesh — the replacement
        for the deprecated bare ``placement=`` kwarg."""
        return cls(None, (), placement=placement)

    @property
    def expert_axes(self) -> tuple:
        return (self.expert_axis if isinstance(self.expert_axis, tuple)
                else (self.expert_axis,))

    @property
    def mode(self) -> str:
        return ("a2a" if all(a in self.token_axes for a in self.expert_axes)
                else "psum")

    @property
    def expert_parallelism(self) -> int:
        n = 1
        for a in self.expert_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def wire_jnp_dtype(self):
        """jnp dtype for a2a payloads, or None for the activation dtype."""
        if self.wire_dtype is None:
            return None
        if self.wire_dtype in ("bf16", "bfloat16"):
            return jnp.bfloat16
        return jnp.dtype(self.wire_dtype)


# ---------------------------------------------------------------------------
# Expert networks (the default expert: a transformer FFN)
# ---------------------------------------------------------------------------


def _ffn_init(rng: jax.Array, num: int, d: int, h: int, act: str, dtype) -> dict:
    ks = jax.random.split(rng, 3)
    si, so = d ** -0.5, h ** -0.5
    shape_i, shape_o = (num, d, h), (num, h, d)
    if num == 0:
        shape_i, shape_o = (d, h), (h, d)
    p = {"wo": (jax.random.normal(ks[2], shape_o) * so).astype(dtype)}
    if act == "swiglu":
        p["wi_gate"] = (jax.random.normal(ks[0], shape_i) * si).astype(dtype)
        p["wi_up"] = (jax.random.normal(ks[1], shape_i) * si).astype(dtype)
    else:
        p["wi"] = (jax.random.normal(ks[0], shape_i) * si).astype(dtype)
    return p


def _act(h: jax.Array, act: str) -> jax.Array:
    if act == "gelu":
        return jax.nn.gelu(h)
    if act == "rwkv":  # squared relu (RWKV channel-mix)
        return jnp.square(jax.nn.relu(h))
    return jax.nn.silu(h)  # swiglu gate handled by caller


def dense_ffn(params: dict, x: jax.Array, act: str) -> jax.Array:
    """Plain (non-expert) FFN on (..., d)."""
    if act == "swiglu":
        h = jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])
    else:
        h = _act(x @ params["wi"], act)
    return h @ params["wo"]


def expert_ffn(params: dict, xs: jax.Array, act: str) -> jax.Array:
    """Default ``expert_fn``: batched per-expert FFN on (E, n, d) buffers.

    One einsum per projection = one big GeMM batched over experts — the MXU
    analogue of FMoELinear's multi-stream concurrent expert execution (C2).
    """
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("end,edh->enh", xs, params["wi_gate"]))
        h = h * jnp.einsum("end,edh->enh", xs, params["wi_up"])
    else:
        h = _act(jnp.einsum("end,edh->enh", xs, params["wi"]), act)
    return jnp.einsum("enh,ehd->end", h, params["wo"])


def _expert_ws(params: dict, act: str) -> tuple:
    """(wi_gate, wi_up) for swiglu, (wi,) otherwise — the kernels' contract."""
    return ((params["wi_gate"], params["wi_up"]) if act == "swiglu"
            else (params["wi"],))


def expert_ffn_pallas(params: dict, xs: jax.Array, act: str) -> jax.Array:
    """expert_fn backed by the Pallas grouped-GEMM kernel (equal-size groups)."""
    from repro.kernels import grouped_gemm as gg
    from repro.kernels import ops  # lazy: keeps core importable without kernels

    E, n, d = xs.shape
    flat = xs.reshape(E * n, d)
    sizes = jnp.full((E,), n, jnp.int32)
    aligned = n % gg.DEFAULT_BM == 0  # whole row tiles: skip pad/gather
    if act == "swiglu":
        h = jax.nn.silu(ops.grouped_matmul(flat, params["wi_gate"], sizes,
                                           "pallas", gg.DEFAULT_BM, aligned))
        h = h * ops.grouped_matmul(flat, params["wi_up"], sizes,
                                   "pallas", gg.DEFAULT_BM, aligned)
    else:
        h = _act(ops.grouped_matmul(flat, params["wi"], sizes,
                                    "pallas", gg.DEFAULT_BM, aligned), act)
    return ops.grouped_matmul(h, params["wo"], sizes,
                              "pallas", gg.DEFAULT_BM, aligned).reshape(E, n, -1)


def expert_ffn_fused(params: dict, xs: jax.Array, act: str) -> jax.Array:
    """expert_fn backed by the fused GEMM1+act+GEMM2 Pallas kernel.

    Unlike the two-pass path, the (M, H) hidden activation never
    materializes in HBM — in the forward or the backward (fused dX / dW
    kernels via the custom_vjp; see repro.kernels.fused_ffn_bwd).
    """
    from repro.kernels import fused_ffn as ffk
    from repro.kernels import ops  # lazy: keeps core importable without kernels

    E, n, d = xs.shape
    flat = xs.reshape(E * n, d)
    sizes = jnp.full((E,), n, jnp.int32)
    aligned = n % ffk.DEFAULT_BM == 0  # whole row tiles: skip pad/gather
    return ops.fused_grouped_ffn(flat, _expert_ws(params, act), params["wo"],
                                 sizes, act, ffk.DEFAULT_BM, ffk.DEFAULT_BH,
                                 aligned).reshape(E, n, -1)


EXPERT_FNS: dict[str, Callable] = {
    "einsum": expert_ffn,
    "pallas": expert_ffn_pallas,
    "fused": expert_ffn_fused,
}


# Ragged (dropless) analogues: expert-sorted (T*k, d) rows with variable
# group sizes.  "einsum"/"pallas" run the two-pass grouped GEMMs;
# "fused" runs the fused fwd+bwd kernels — same selection axis as
# EXPERT_FNS so every dispatch mode exposes every impl.


def ragged_ffn_two_pass(params: dict, xs: jax.Array, group_sizes: jax.Array,
                        act: str, impl: str = "pallas") -> jax.Array:
    from repro.kernels import ops

    return ops.ffn_two_pass(xs, _expert_ws(params, act), params["wo"],
                            group_sizes, act, impl)


def ragged_ffn_fused(params: dict, xs: jax.Array, group_sizes: jax.Array,
                     act: str) -> jax.Array:
    from repro.kernels import ops

    return ops.fused_grouped_ffn(xs, _expert_ws(params, act), params["wo"],
                                 group_sizes, act)


RAGGED_FNS: dict[str, Callable] = {
    # "einsum" = the XLA grouped-GEMM primitive (ragged_dot), matching the
    # batched-XLA-GEMMs contract of EXPERT_FNS["einsum"] on this path
    "einsum": functools.partial(ragged_ffn_two_pass, impl="xla"),
    "pallas": ragged_ffn_two_pass,
    "fused": ragged_ffn_fused,
}


# ---------------------------------------------------------------------------
# Layer init
# ---------------------------------------------------------------------------


def fmoe_init(rng: jax.Array, d_model: int, cfg: MoEConfig, *, act: str = "swiglu",
              d_ff_dense: int = 0, dtype=jnp.float32) -> dict:
    """Parameters for one MoE FFN block."""
    ks = jax.random.split(rng, 4)
    params = {
        "router": router_init(ks[0], d_model, cfg, dtype=jnp.float32),
        "experts": _ffn_init(ks[1], cfg.num_experts, d_model,
                             cfg.d_expert_hidden, act, dtype),
    }
    if cfg.num_shared_experts:
        params["shared"] = _ffn_init(
            ks[2], 0, d_model, cfg.num_shared_experts * cfg.d_expert_hidden,
            act, dtype)
    if cfg.dense_residual:
        params["dense"] = _ffn_init(ks[3], 0, d_model, d_ff_dense or cfg.d_expert_hidden,
                                    act, dtype)
    return params


# ---------------------------------------------------------------------------
# Local (single-worker) forward — paper §4 reordering
# ---------------------------------------------------------------------------


def _route_table(place, l2p):
    """The in-graph logical->physical gate-id table for one layer.

    ``l2p`` is the per-layer table threaded through the models' layer scan
    (a traced (E,) int32 array — see models/lm.py); when absent, the shared
    plan's static table applies.  None = identity routing.
    """
    if l2p is not None:
        return jnp.asarray(l2p, jnp.int32)
    if place is not None and not place.is_identity:
        return jnp.asarray(place.logical_to_physical)
    return None


def _axes_size(dist: "DistConfig", axes) -> int:
    """Static number of ranks in the given mesh-axis group (1 if empty)."""
    n = 1
    for a in axes:
        n *= int(dist.mesh.shape[a])
    return n


def _imbalance(owned_load: jax.Array, mp: int, E_local: int) -> jax.Array:
    """max/mean of per-expert-rank received load from an already-global
    physical-order owned-expert load vector (no collective of its own)."""
    per_rank = owned_load.astype(jnp.float32).reshape(mp, E_local).sum(axis=1)
    return per_rank.max() / jnp.maximum(per_rank.mean(), 1e-6)


def _aux_loss(router: dict, x: jax.Array, g, cfg: MoEConfig) -> jax.Array:
    """Balance loss, plus the StableMoE stage-1 distillation term whenever a
    frozen-router-to-be is riding along (its gradient reaches only
    ``w_frozen``, so the live gate is unperturbed)."""
    aux = load_balance_loss(g.probs, g.expert_ids, cfg.num_experts)
    if cfg.router != "frozen" and "w_frozen" in router:
        aux = aux + router_distill_loss(router, x, g)
    return aux


def _ec_route(router: dict, x: jax.Array, cfg: MoEConfig, table):
    """Expert-choice routing shared by the four MoE paths.

    Returns (C, token_idx (E, C) logical order, ti_phys (E, C) physical
    order, weights (E, C), logits).  Uniform exact capacities mean the
    physical grid is a pure row permutation of the logical one.
    """
    C = D.ec_capacity(x.shape[0], cfg.num_experts, cfg.capacity_factor)
    token_idx, weights, _, logits = expert_choice_forward(
        router, x, cfg, capacity=C)
    return C, token_idx, D.ec_to_physical(token_idx, table), weights, logits


def _ec_flat_load(E: int) -> jax.Array:
    """Expert-choice load is flat by construction — every expert takes
    exactly C rows (the LoadMonitor sees imbalance 1.0 and the placement
    planner treats it as a no-replan signal)."""
    return jnp.full((E,), 1.0 / E, jnp.float32)


def _moe_local(x: jax.Array, router: dict, experts: dict, cfg: MoEConfig,
               act: str, expert_fn: Callable, rng=None, placement=None,
               impl: str = "einsum", l2p=None):
    T = x.shape[0]
    table = _route_table(placement, l2p)
    if cfg.router == "expert_choice":
        C, token_idx, ti_phys, ec_w, logits = _ec_route(router, x, cfg, table)
        E = cfg.num_experts
        if cfg.dispatch == "ragged":
            # the degenerate uniform-ragged case: group_sizes == C everywhere
            xs = x[ti_phys.reshape(-1)]  # (E*C, d) physical-expert-major
            ys = RAGGED_FNS[impl](experts, xs,
                                  jnp.full((E,), C, jnp.int32), act)
            out = ys.reshape(E, C, -1)
        else:
            out = expert_fn(experts, x[ti_phys], act)  # (E, C, dout)
        if table is not None:
            out = out[table]  # combine in logical order (bitwise invariant)
        y = D.combine_ec(out, token_idx, ec_w, T)
        metrics = MoEMetrics(jnp.zeros(()), router_z_loss(logits),
                             _ec_flat_load(E), jnp.zeros(()),
                             obs_counters.local_counters(dropped=jnp.zeros(())))
        return y, metrics
    g = route_tokens(router, x, cfg, rng=rng)
    expert_ids = g.expert_ids
    if table is not None:
        # experts arrive in the plan's physical order; route through the
        # logical->physical index table (routing semantics unchanged)
        expert_ids = table[expert_ids]
    if cfg.dispatch == "ragged":
        plan = D.make_ragged_plan(expert_ids, cfg.num_experts)
        xs = D.dispatch_ragged(x, plan)  # (T*k, d) expert-sorted
        # impl is a first-class axis here too: the grouped kernels take
        # variable group sizes directly, so "fused" runs the fused fwd+bwd
        # on the dropless path (no capacity padding, no (M, H) in HBM)
        ys = RAGGED_FNS[impl](experts, xs, plan.group_sizes, act)
        y = D.combine_ragged(ys, plan, g.combine_weights)
        load, drop = load_metrics(plan.group_sizes, None, T * cfg.top_k)
    else:
        C = D.expert_capacity(T, cfg.num_experts, cfg.top_k, cfg.capacity_factor)
        plan = D.make_capacity_plan(expert_ids, cfg.num_experts, C)
        buf = D.dispatch_capacity(x, plan, cfg.num_experts)  # scatter (Fig 4)
        out = expert_fn(experts, buf, act)  # batched per-expert GeMM
        y = D.combine_capacity(out, plan, g.combine_weights)  # gather
        load, drop = load_metrics(plan.load, plan.keep, T * cfg.top_k)
    if table is not None:
        load = load[table]  # logical order
    metrics = MoEMetrics(_aux_loss(router, x, g, cfg),
                         router_z_loss(g.logits), load, drop,
                         obs_counters.local_counters(
                             dropped=drop * (T * cfg.top_k)))
    return y, metrics


# ---------------------------------------------------------------------------
# Distributed forward — paper §3.2 global data exchange
# ---------------------------------------------------------------------------


def _moe_a2a(x, router, experts, extra, shadow, l2p, cfg: MoEConfig, act,
             expert_fn, dist: DistConfig, impl: str = "einsum", rng=None):
    """Tokens sharded over all mesh axes; experts sharded over ``expert_axis``.

    Per-rank: gate -> dispatch into (E, C, d) -> all-to-all over the expert
    axis -> local experts compute on (E_local, mp*C, d) -> reverse all-to-all
    -> combine.  The Fig-2 "exchange sizes" step survives as the counts
    all-to-all feeding the load monitor.

    With ``dist.overlap_chunks > 1`` the payload exchange runs as the §5.2
    smart schedule instead: capacity micro-shards whose ppermute-decomposed
    sends/returns pipeline with the expert compute (repro.core.pipeline) —
    bit-exact vs the serial schedule.  ``dist.wire_dtype`` casts payloads
    across the wire on either path.

    With a ``dist.placement``, ``experts`` hold only the *owned* physical
    slots and ``shadow`` the replicated hot experts: gate ids go through the
    plan's index table, owned buffer rows take the (possibly shrunk) a2a,
    and shadowed rows are computed locally from the broadcast ``shadow``
    weights — skipped in the exchanged payload entirely.
    """
    from repro.placement.shadow import merge_outputs, shadow_spec, split_buffer

    ax = dist.expert_axis
    mp = dist.expert_parallelism
    E = cfg.num_experts
    t, d = x.shape
    place = dist.placement
    if place is not None and place.is_identity and l2p is None:
        place = None
    table = _route_table(place, l2p)

    ec = cfg.router == "expert_choice"
    if ec:
        # experts pick tokens: exact uniform capacities, the (E, C, d) buffer
        # is a plain gather and the exchange machinery below runs unchanged
        C, token_idx, ti_phys, ec_w, ec_logits = _ec_route(router, x, cfg,
                                                           table)
        g = plan = None
        spec = shadow_spec(place, E, C)
        # the planner's capacity shrink prices padded a2a bytes; EC buffers
        # are exactly sized, so a shrink would only drop — restore C for all
        spec = spec._replace(main_capacity=C, shadow_capacity=C)
        buf = x[ti_phys]  # (E, C, d)
        assigned = jnp.full((E,), C, jnp.int32)
    else:
        if rng is not None:
            for a_ in dist.token_axes:
                rng = jax.random.fold_in(rng, jax.lax.axis_index(a_))
        g = route_tokens(router, x, cfg, rng=rng)
        C = D.expert_capacity(t, E, cfg.top_k, cfg.capacity_factor)
        spec = shadow_spec(place, E, C)
        expert_ids = g.expert_ids
        if table is not None:
            expert_ids = table[expert_ids]
        if place is not None:
            plan = D.make_capacity_plan(expert_ids, E,
                                        tuple(int(c) for c in spec.capacities))
        else:
            plan = D.make_capacity_plan(expert_ids, E, C)
        buf = D.dispatch_capacity(x, plan, E)  # (E, width, d)
        assigned = plan.load
    E_ns = spec.num_owned  # physical slots [0, E_ns) take the a2a
    E_local = E_ns // mp
    Cm = spec.main_capacity
    buf, buf_shadow = split_buffer(buf, spec)

    # ---- global data exchange (Fig 2), owned experts only ----
    n_chunks = pipeline.resolve_chunks(dist.overlap_chunks or 1, Cm)
    counts = assigned[:E_ns].reshape(mp, E_local)
    # §5.2 follow-on: with chunking the counts exchange decomposes into
    # ppermutes too, so the pipelined HLO has no blocking all-to-all at all
    incoming = pipeline.counts_all_to_all(counts, ax, mp,
                                          decompose=n_chunks > 1)  # per-src
    wire = dist.wire_jnp_dtype

    def compute(b):
        # b: (E_local, rows, d) row-independent expert compute
        if dist.tp_axis:
            # Expert-internal TP: expert hidden dims stay sharded over
            # tp_axis (no per-layer FSDP weight all-gather / grad
            # reduce-scatter).  Different tp ranks hold different tokens, so
            # gather tokens first and reduce-scatter the partial outputs
            # back to own shard.
            b = jax.lax.all_gather(b, dist.tp_axis, axis=1, tiled=True)
            o = expert_fn(experts, b, act)  # partial over hidden shards
            return jax.lax.psum_scatter(o, dist.tp_axis, scatter_dimension=1,
                                        tiled=True)
        return expert_fn(experts, b, act)

    # §5.2 smart schedule: pipeline the exchange with expert compute in
    # capacity micro-shards; shadowed experts fill the first wire bubble.
    # n_chunks == 1 runs the same helper as one serial exchange each way.
    fill_fn = (lambda: expert_fn(shadow, buf_shadow, act)) if shadow else None
    out, out_shadow = pipeline.pipelined_expert_exchange(
        buf.reshape(mp, E_local, Cm, d), ax, mp, n_chunks, compute,
        fill_fn=fill_fn, wire_dtype=wire, decompose=n_chunks > 1)
    out = out.reshape(E_ns, Cm, -1)

    # ---- shadowed hot experts: every rank, own tokens, zero a2a bytes ----
    out = merge_outputs(out, out_shadow, spec)
    if ec:
        out_log = out if table is None else out[table]
        y = D.combine_ec(out_log, token_idx, ec_w, t)
    else:
        y = D.combine_capacity(out, plan, g.combine_weights)

    # shared-expert / dense-residual FFNs on the LOCAL token shard with
    # replicated weights — avoids the full-token replication SPMD falls back
    # to when these cross the shard_map boundary (§Perf fix)
    for p in extra.values():
        y = y + dense_ffn(p, x, act)

    # ---- metrics: the Fig-2 counts exchange feeds the load monitor ----
    axes = tuple(dist.token_axes)
    other_axes = tuple(a for a in axes if a not in dist.expert_axes)
    recv_local = incoming.sum(0)  # (E_local,) tokens arriving at my experts
    load_global = jax.lax.all_gather(recv_local, ax, tiled=True)  # (E_ns,)
    if other_axes:
        load_global = jax.lax.psum(load_global, other_axes)
    if spec.num_shadow:
        # shadowed experts never cross the wire; their global load is the
        # psum of local assignment counts over every token-holding axis
        shadow_load = jax.lax.psum(assigned[E_ns:], axes)
        load_global = jnp.concatenate([load_global,
                                       shadow_load.astype(load_global.dtype)])
    if dist.obs:
        # telemetry derived BEFORE the logical-order gather: the owned
        # physical slots [0, E_ns) are what the exchange actually moved
        imbalance = _imbalance(load_global[:E_ns], mp, E_local)
        shadow_hits = (shadow_load.astype(jnp.float32).sum()
                       if spec.num_shadow else jnp.zeros(()))
    if table is not None:
        # back to logical expert order for the monitor
        load_global = load_global[table]
    load, _ = load_metrics(load_global, None,
                           jnp.maximum(load_global.sum(), 1))
    if ec:
        drop = jnp.zeros(())  # exact capacities: nothing to drop
    else:
        _, drop = load_metrics(plan.load, plan.keep, t * cfg.top_k)
    drop_pm = jax.lax.pmean(drop, axes)
    if dist.obs:
        obs = obs_counters.exchange_counters(
            frac=pipeline.wire_fraction(mp, decompose=n_chunks > 1),
            fwd_rows=E_ns * Cm, d_in=d, in_dtype=x.dtype,
            ret_rows=E_ns * Cm, d_out=out.shape[-1], out_dtype=out.dtype,
            counts_elems=E_ns, wire_dtype=wire,
            dropped=drop_pm * (t * cfg.top_k * _axes_size(dist, axes)),
            shadow_hits=shadow_hits, imbalance=imbalance)
    else:
        obs = ObsCounters.zero()
    metrics = MoEMetrics(
        jnp.zeros(()) if ec
        else jax.lax.pmean(_aux_loss(router, x, g, cfg), axes),
        jax.lax.pmean(router_z_loss(ec_logits if ec else g.logits), axes),
        load,
        drop_pm,
        obs,
    )
    return y, metrics


def _moe_a2a_ragged(x, router, experts, extra, shadow, l2p, cfg: MoEConfig,
                    act, expert_fn, dist: DistConfig, impl: str = "einsum",
                    rng=None):
    """Dropless (ragged) expert parallelism — the load-sized exchange.

    Where the capacity path pads every expert to C rows before the wire,
    this path moves the rank's expert-*sorted* rows in per-peer shards:

      1. counts all-to-all — each rank tells peer p how many rows it routed
         to each of p's experts (the Fig-2 "exchange sizes" step, now load-
         bearing instead of monitor-only);
      2. payload exchange — sorted rows scattered into ``(mp, bound, d)``
         pad-to-max-per-peer shards (``dist.ragged_bound``; default
         T_local*k never drops), each shard a ppermute-decomposable
         micro-shardable exchange (core/pipeline), wire-cast per
         ``dist.wire_dtype``;
      3. the receiver compacts the valid prefixes (lengths = received
         counts) into one expert-sorted array and runs the grouped ragged
         kernels (RAGGED_FNS[impl] — einsum/pallas/fused, incl. the fused
         fwd+bwd kernel with its variable/empty group support);
      4. the return exchange inverts the permutation (tiled a2a is its own
         inverse) and ``combine_ragged`` applies the gate weights.

    Shadowed hot experts (dist.placement) never cross the wire: their rows
    are the sorted array's tail segment, computed locally from the broadcast
    ``shadow`` weights inside the first chunk's wire bubble.
    """
    from repro.core import comm

    del expert_fn  # the grouped ragged kernels (RAGGED_FNS[impl]) apply
    ax = dist.expert_axis
    mp = dist.expert_parallelism
    E = cfg.num_experts
    t, d = x.shape
    place = dist.placement
    if place is not None and place.is_identity and l2p is None:
        place = None
    table = _route_table(place, l2p)

    E_ns = E  # physical slots [0, E_ns) take the a2a; the rest are shadowed
    if place is not None:
        E_ns = place.num_owned
    E_local = E_ns // mp
    ec = cfg.router == "expert_choice"
    if ec:
        # exact capacities = the degenerate uniform-ragged case: the sorted
        # rows are the gathered (E, C) token grid flattened physical-major,
        # with group_sizes == C everywhere — the exchange runs unchanged
        C, token_idx, ti_phys, ec_w, ec_logits = _ec_route(router, x, cfg,
                                                           table)
        g = plan = None
        n = E * C
        gs_phys = jnp.full((E,), C, jnp.int32)
        x_sorted = x[ti_phys.reshape(-1)]  # (n, d)
    else:
        if rng is not None:
            for a_ in dist.token_axes:
                rng = jax.random.fold_in(rng, jax.lax.axis_index(a_))
        g = route_tokens(router, x, cfg, rng=rng)
        expert_ids = g.expert_ids
        if table is not None:
            expert_ids = table[expert_ids]
        n = t * cfg.top_k
        plan = D.make_ragged_plan(expert_ids, E)  # full physical-order sort
        gs_phys = plan.group_sizes
        x_sorted = D.dispatch_ragged(x, plan)  # (n, d)
    B = dist.ragged_bound or n
    xplan = D.make_ragged_xplan(gs_phys, n, E_ns, mp, B)
    send = (jnp.zeros((mp * B, d), x.dtype)
            .at[xplan.send_dest].set(x_sorted, mode="drop")
            .reshape(mp, B, d))

    # shadow filler: the sorted tail [num_owned_rows, n) shifted to offset 0
    # (an exchange-free grouped-FFN call issued inside the first wire bubble)
    fill_fn = None
    shadow_dest = None
    if shadow:
        i = jnp.arange(n, dtype=jnp.int32)
        shadow_dest = jnp.where(i >= xplan.num_owned_rows,
                                i - xplan.num_owned_rows, n).astype(jnp.int32)
        xs_sh = jnp.zeros((n, d), x.dtype).at[shadow_dest].set(x_sorted,
                                                               mode="drop")
        fill_fn = lambda: RAGGED_FNS[impl](shadow, xs_sh,
                                           gs_phys[E_ns:], act)

    wire = dist.wire_jnp_dtype
    node_ax = dist.node_axis
    n_nodes = int(dist.mesh.shape[node_ax]) if node_ax in dist.expert_axes \
        else 1
    hier = 1 < n_nodes < mp
    agg_dropped = None
    if not hier:
        n_chunks = pipeline.resolve_chunks(dist.overlap_chunks or 1, B)
        recv, incoming, fill_out = comm.exchange_ragged(
            send, xplan.peer_counts, ax, mp, n_chunks=n_chunks,
            wire_dtype=wire, fill_fn=fill_fn)

        # compact the valid shard prefixes into expert-sorted rows (src-major
        # within an expert = global token order for contiguous token shards)
        cplan, gs_local = D.ragged_recv_compact(incoming, B)
        xs = (jnp.zeros((mp * B, d), x.dtype)
              .at[cplan].set(recv.reshape(mp * B, d), mode="drop"))
        ys = RAGGED_FNS[impl](experts, xs, gs_local, act)
        out = ys.at[cplan].get(mode="fill", fill_value=0)  # to shard slots

        ret = comm.return_ragged(out.reshape(mp, B, -1), ax, mp,
                                 n_chunks=n_chunks, wire_dtype=wire)
    else:
        # ---- two-level exchange: aggregate on the node, slim across it ----
        if dist.expert_axes[0] != node_ax:
            raise ValueError(
                f"node_axis {node_ax!r} must lead expert_axes "
                f"{dist.expert_axes!r} (ranks are node-major)")
        inner_axes = tuple(a for a in dist.expert_axes if a != node_ax)
        inner_ax = inner_axes[0] if len(inner_axes) == 1 else inner_axes
        n_inner = mp // n_nodes
        IB = dist.inter_bound or n_inner * B  # slim shard rows (0 = no-drop)
        # only the slow inter-node leg is chunked/pipelined; the node-local
        # hops ride the fast links serially (and decomposed alongside)
        n_chunks = pipeline.resolve_chunks(dist.overlap_chunks or 1, IB)
        decomp = n_chunks > 1
        shards, cnt_agg = comm.exchange_ragged_intra(
            send.reshape(n_nodes, n_inner, B, d),
            xplan.peer_counts.reshape(n_nodes, n_inner, E_local),
            inner_ax, n_inner, decompose=decomp, wire_dtype=wire)
        aplan = D.make_hier_agg(cnt_agg, B, IB)
        agg_dropped = aplan.dropped
        slim = (jnp.zeros((n_nodes * IB, d), x.dtype)
                .at[aplan.agg_dest].set(
                    shards.reshape(n_nodes * n_inner * B, d), mode="drop")
                .reshape(n_nodes, IB, d))
        if decomp and impl in ("pallas", "fused"):
            # per-received-chunk expert compute: each inter chunk's counts
            # are known before its payload lands, so the grouped kernels run
            # on chunk c while chunk c+1 is in flight.  Gated to the Pallas
            # kernels: they accumulate group-relative and stay bitwise under
            # regrouping, XLA's ragged einsum does not (see _moe_psum).
            # Forward values are bitwise-identical to the serial compute;
            # the backward would NOT be (splitting the grouped-GEMM weight
            # -grad accumulation across chunks reassociates the f32 sums),
            # so a custom_vjp pins the backward to the serial leg's VJP —
            # both directions stay bit-exact vs. the flat exchange.
            w_rows = IB // n_chunks
            dt = x.dtype
            incoming = pipeline.counts_all_to_all(
                aplan.kept_counts.reshape(n_nodes, n_inner * E_local),
                node_ax, n_nodes, decompose=True).reshape(cnt_agg.shape)
            cplan, gs_local = D.ragged_recv_compact_hier(incoming, IB)
            cdest, cgs = D.hier_chunk_plans(incoming, IB, n_chunks)

            def _serial_leg(ex, slim_, cplan_, gs_):
                recv = pipeline.chunked_all_to_all(
                    slim_, node_ax, n_nodes, n_chunks, wire_dtype=wire,
                    decompose=True)
                xs = (jnp.zeros((n_nodes * IB, d), dt)
                      .at[cplan_].set(recv.reshape(n_nodes * IB, d),
                                      mode="drop"))
                ys_ = RAGGED_FNS[impl](ex, xs, gs_, act)
                out_ = ys_.at[cplan_].get(mode="fill", fill_value=0)
                return pipeline.chunked_all_to_all(
                    out_.reshape(n_nodes, IB, -1), node_ax, n_nodes,
                    n_chunks, wire_dtype=wire, decompose=True)

            # plan arrays ride as explicit primals (jax 0.4.x custom_vjp
            # rejects closed-over tracers); their cotangents are float0
            @jax.custom_vjp
            def _inter_leg(ex, slim_, cplan_, gs_, cdest_, cgs_):
                def chunk_fn(rc, c):
                    mini = (jnp.zeros((n_nodes * w_rows, d), dt)
                            .at[cdest_[c]].set(
                                rc.reshape(n_nodes * w_rows, d), mode="drop"))
                    ys_c = RAGGED_FNS[impl](ex, mini, cgs_[c], act)
                    return (ys_c.at[cdest_[c]].get(mode="fill", fill_value=0)
                            .reshape(n_nodes, w_rows, -1))
                ret_, _ = pipeline.hier_ragged_pipeline(
                    slim_, node_ax, n_nodes, n_chunks, chunk_fn,
                    wire_dtype=wire)
                return ret_

            def _inter_fwd(ex, slim_, cplan_, gs_, cdest_, cgs_):
                return (_inter_leg(ex, slim_, cplan_, gs_, cdest_, cgs_),
                        (ex, slim_, cplan_, gs_, cdest_, cgs_))

            def _inter_bwd(res, g):
                ex, slim_, cplan_, gs_, cdest_, cgs_ = res
                _, vjp = jax.vjp(
                    lambda e, s: _serial_leg(e, s, cplan_, gs_), ex, slim_)
                d_ex, d_slim = vjp(g)
                f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)
                return (d_ex, d_slim, f0(cplan_), f0(gs_), f0(cdest_),
                        f0(cgs_))

            _inter_leg.defvjp(_inter_fwd, _inter_bwd)
            fill_out = fill_fn() if fill_fn is not None else None
            ret_slim = _inter_leg(experts, slim, cplan, gs_local, cdest, cgs)
        else:
            recv, incoming, fill_out = comm.exchange_ragged_inter(
                slim, aplan.kept_counts, node_ax, n_nodes, n_chunks=n_chunks,
                wire_dtype=wire, fill_fn=fill_fn)
            cplan, gs_local = D.ragged_recv_compact_hier(incoming, IB)
            xs = (jnp.zeros((n_nodes * IB, d), x.dtype)
                  .at[cplan].set(recv.reshape(n_nodes * IB, d), mode="drop"))
            ys = RAGGED_FNS[impl](experts, xs, gs_local, act)
            out = ys.at[cplan].get(mode="fill", fill_value=0)
            ret_slim = comm.return_ragged_inter(
                out.reshape(n_nodes, IB, -1), aplan.kept_counts, incoming,
                node_ax, n_nodes, n_chunks=n_chunks, wire_dtype=wire)
        # de-aggregate (outputs back to padded sibling shards), then invert
        # the intra hop — ret lands in the flat (mp, B) shard layout
        d_out = ret_slim.shape[-1]
        padded = (ret_slim.reshape(n_nodes * IB, d_out)
                  .at[aplan.agg_dest].get(mode="fill", fill_value=0)
                  .reshape(n_nodes, n_inner, B, d_out))
        ret = comm.return_ragged_intra(
            padded, inner_ax, n_inner, decompose=decomp,
            wire_dtype=wire).reshape(mp, B, d_out)
    y_sorted = (ret.reshape(mp * B, -1)
                .at[xplan.send_dest].get(mode="fill", fill_value=0))
    if shadow:
        y_sorted = y_sorted + fill_out.at[shadow_dest].get(mode="fill",
                                                           fill_value=0)
    if ec:
        out_grid = y_sorted.reshape(E, C, -1)
        out_log = out_grid if table is None else out_grid[table]
        y = D.combine_ec(out_log, token_idx, ec_w, t)
    else:
        y = D.combine_ragged(y_sorted, plan, g.combine_weights)

    for p in extra.values():  # see _moe_a2a (§Perf residual fix)
        y = y + dense_ffn(p, x, act)

    # ---- metrics: global assigned load + bound-overflow drops ----
    axes = tuple(dist.token_axes)
    load_global = jax.lax.psum(gs_phys, axes)
    if dist.obs:
        # physical order: owned slots [0, E_ns) took the exchange, the tail
        # [E_ns, E) are shadowed hot experts served locally on every rank
        imbalance = _imbalance(load_global[:E_ns], mp, E_local)
        shadow_hits = (load_global[E_ns:].astype(jnp.float32).sum()
                       if E_ns < E else jnp.zeros(()))
    if table is not None:
        load_global = load_global[table]
    load, _ = load_metrics(load_global, None,
                           jnp.maximum(load_global.sum(), 1))
    dropped = (xplan.num_owned_rows - xplan.keep.sum()).astype(jnp.float32)
    drop_pm = jax.lax.pmean(dropped / n, axes)
    if agg_dropped is not None:
        # rows the forwarding agents truncated at the inter bound — summed
        # over agents (= ranks), normalized to the same global fraction
        drop_pm = drop_pm + (jax.lax.psum(agg_dropped, axes)
                             / (n * _axes_size(dist, axes)))
    if dist.obs:
        dropped_global = drop_pm * (n * _axes_size(dist, axes))
        if hier:
            obs = obs_counters.hier_exchange_counters(
                intra_frac=pipeline.wire_fraction(n_inner, decompose=decomp),
                inter_frac=pipeline.wire_fraction(n_nodes, decompose=decomp),
                intra_rows=mp * B, inter_rows=n_nodes * IB,
                d_in=d, in_dtype=x.dtype, d_out=ret.shape[-1],
                out_dtype=ret.dtype, counts_elems=E_ns, wire_dtype=wire,
                dropped=dropped_global, shadow_hits=shadow_hits,
                imbalance=imbalance)
        else:
            obs = obs_counters.exchange_counters(
                frac=pipeline.wire_fraction(mp, decompose=n_chunks > 1),
                fwd_rows=mp * B, d_in=d, in_dtype=x.dtype,
                ret_rows=mp * B, d_out=ret.shape[-1], out_dtype=ret.dtype,
                counts_elems=E_ns, wire_dtype=wire,
                dropped=dropped_global,
                shadow_hits=shadow_hits, imbalance=imbalance)
    else:
        obs = ObsCounters.zero()
    metrics = MoEMetrics(
        jnp.zeros(()) if ec
        else jax.lax.pmean(_aux_loss(router, x, g, cfg), axes),
        jax.lax.pmean(router_z_loss(ec_logits if ec else g.logits), axes),
        load,
        drop_pm,
        obs,
    )
    return y, metrics


def _moe_psum(x, router, experts, extra, shadow, l2p, cfg: MoEConfig, act,
              expert_fn, dist: DistConfig, impl: str = "einsum", rng=None):
    """Tokens NOT sharded over the expert axis (decode): every rank gates all
    its tokens, computes only its local experts, partial outputs psum over the
    expert axis.  No all-to-all; communication = one psum of (t, d).

    ``cfg.dispatch == "ragged"`` swaps the capacity buffers for the sorted
    dropless layout: the rank's local experts own one contiguous segment of
    the expert-sorted rows (shifted to offset 0, grouped kernels on variable
    sizes), so the psum mode is dropless too — the dispatch × dist matrix
    has no capacity-only corner left.

    A ``dist.placement`` is honored in full (the ROADMAP's "placement-aware
    psum (decode) shadowing"): gate ids go through the plan's table, owned
    experts are permuted into load-balanced per-rank blocks, and shadowed
    hot experts are *skipped in the psum reduction* — every model-axis rank
    computes them on its own (identical) tokens from the replicated
    ``shadow`` weights, and their contribution is added locally after the
    psum.  There is no wire saving here (the psum payload is (t, d) either
    way); the win is the decode critical path: without shadowing the rank
    owning a hot expert serializes the whole reduction, with it the hot
    compute is replicated and the residual owned load greedy-balanced.
    Bitwise-identical to the unshadowed reduction under the same layout:
    whenever a placement is engaged, per-slot contributions reduce across
    ranks *before* the fixed-order k-sum (dispatch.combine_capacity_slots),
    so no rounding ever observes which rank served a slot — toggling
    ``num_shadow`` or permuting experts cannot move the output by even an
    ulp.  The plain (no-placement) path keeps the cheaper combined (t, d)
    psum — slot-wise reduction costs top_k x the payload, which the tiny
    decode reduction absorbs but the training psum *fallback* (large t)
    should not pay for nothing — so placed vs plain differs by combine
    rounding order (ulp), never semantics.  One further exception: ragged
    dispatch under the "einsum" impl, whose XLA ragged_dot lowering is
    group-structure-sensitive (ulp-level); the tile-aligned pallas/fused
    kernels accumulate group-relative and stay bitwise.

    The planner's ``capacity_scale`` shrink prices a2a bytes; there is no
    wire here, so a shrunk owned buffer would only add drop risk — the
    capacity branch always restores the full per-expert capacity.
    """
    from repro.placement.shadow import (merge_outputs, shadow_only,
                                        shadow_spec, split_buffer)

    ax = dist.expert_axis
    mp = dist.expert_parallelism
    E = cfg.num_experts
    t = x.shape[0]
    place = dist.placement
    if place is not None and place.is_identity and l2p is None:
        place = None
    table = _route_table(place, l2p)

    rank = 0  # row-major rank within the (possibly tuple) expert axis group
    for a in dist.expert_axes:
        rank = rank * dist.mesh.shape[a] + jax.lax.axis_index(a)
    if cfg.router == "expert_choice":
        return _moe_psum_ec(x, router, experts, extra, shadow, table, rank,
                            cfg, act, expert_fn, dist, impl)
    if rng is not None:
        for a_ in dist.token_axes:
            rng = jax.random.fold_in(rng, jax.lax.axis_index(a_))
    g = route_tokens(router, x, cfg, rng=rng)
    expert_ids = g.expert_ids
    if table is not None:
        expert_ids = table[expert_ids]
    # layout-invariant slot-wise reduction only when a placement is engaged;
    # the plain path keeps the k-fold-cheaper combined psum (see docstring)
    slotwise = table is not None or bool(shadow)
    if cfg.dispatch == "ragged":
        E_ns = place.num_owned if place is not None else E
        E_local = E_ns // mp
        n = t * cfg.top_k
        plan = D.make_ragged_plan(expert_ids, E)
        x_sorted = D.dispatch_ragged(x, plan)  # (n, d)
        offs = jnp.cumsum(plan.group_sizes) - plan.group_sizes  # exclusive
        gs_local = jax.lax.dynamic_slice_in_dim(plan.group_sizes,
                                                rank * E_local, E_local)
        lo = offs[rank * E_local]
        i = jnp.arange(n, dtype=jnp.int32)
        mine = (i >= lo) & (i < lo + gs_local.sum())
        dest = jnp.where(mine, i - lo, n).astype(jnp.int32)  # shift to 0
        xs = jnp.zeros((n, x.shape[1]), x.dtype).at[dest].set(x_sorted,
                                                              mode="drop")
        ys = RAGGED_FNS[impl](experts, xs, gs_local, act)
        y_sorted = ys.at[dest].get(mode="fill", fill_value=0)
        if slotwise:
            # per-slot contributions psum BEFORE the fixed-order k-sum:
            # bitwise-invariant to the expert layout (see
            # dispatch.combine_capacity_slots)
            c = jax.lax.psum(
                D.combine_ragged_slots(y_sorted, plan, g.combine_weights), ax)
            psum_elems, psum_dtype = c.size, c.dtype
            if shadow:
                # shadow rows = the sorted tail [num_owned_rows, n), shifted
                # to offset 0 — computed on every rank, excluded from the psum
                lo_sh = offs[E_ns] if E_ns < E else jnp.int32(n)
                dest_sh = jnp.where(i >= lo_sh, i - lo_sh, n).astype(jnp.int32)
                xs_sh = jnp.zeros((n, x.shape[1]), x.dtype).at[dest_sh].set(
                    x_sorted, mode="drop")
                ys_sh = RAGGED_FNS[impl](shadow, xs_sh,
                                         plan.group_sizes[E_ns:], act)
                y_sh = ys_sh.at[dest_sh].get(mode="fill", fill_value=0)
                c = c + D.combine_ragged_slots(y_sh, plan, g.combine_weights)
            y = c.sum(axis=1)
        else:  # plain path: the cheap combined (t, d) psum
            y = jax.lax.psum(
                D.combine_ragged(y_sorted, plan, g.combine_weights), ax)
            psum_elems, psum_dtype = y.size, y.dtype
        plan_load, plan_keep, denom = plan.group_sizes, None, n
    else:
        C = D.expert_capacity(t, E, cfg.top_k, cfg.capacity_factor)
        spec = shadow_spec(place, E, C)
        if spec.main_capacity != C:
            # the planner's capacity shrink prices a2a bytes; there is no
            # wire here, so honoring it would only add decode-time drops
            spec = spec._replace(main_capacity=C)
        E_ns = spec.num_owned
        E_local = E_ns // mp
        if place is not None:
            plan = D.make_capacity_plan(
                expert_ids, E, tuple(int(c) for c in spec.capacities))
        else:
            plan = D.make_capacity_plan(expert_ids, E, C)
        buf = D.dispatch_capacity(x, plan, E)  # (E, width, d)
        buf_main, buf_shadow = split_buffer(buf, spec)
        buf_local = jax.lax.dynamic_slice_in_dim(buf_main, rank * E_local,
                                                 E_local, axis=0)
        out_local = expert_fn(experts, buf_local, act)  # (E_local, Cm, d)
        out_main = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((E_ns, spec.main_capacity, out_local.shape[-1]),
                      out_local.dtype), out_local, rank * E_local, axis=0)
        # shadow slots stay zero in the psum'd buffer; every model-axis rank
        # serves them locally from the replicated weights instead
        out = merge_outputs(out_main, None, spec)
        if slotwise:
            # per-slot contributions reduce across ranks BEFORE the fixed-
            # order k-sum so the result is bitwise-invariant to the expert
            # layout (an in-rank k-sum would FMA-fuse co-located slot pairs
            # into one rounding)
            c = jax.lax.psum(
                D.combine_capacity_slots(out, plan, g.combine_weights), ax)
            psum_elems, psum_dtype = c.size, c.dtype
            if shadow:
                out_sh = expert_fn(shadow, buf_shadow, act)
                c = c + D.combine_capacity_slots(shadow_only(out_sh, spec),
                                                 plan, g.combine_weights)
            y = c.sum(axis=1)
        else:  # plain path: the cheap combined (t, d) psum
            y = jax.lax.psum(D.combine_capacity(out, plan, g.combine_weights),
                             ax)
            psum_elems, psum_dtype = y.size, y.dtype
        plan_load, plan_keep, denom = plan.load, plan.keep, t * cfg.top_k
    for p in extra.values():  # see _moe_a2a
        y = y + dense_ffn(p, x, act)

    axes = tuple(dist.token_axes)
    load, drop = load_metrics(plan_load, plan_keep, denom)
    pm = (lambda v: jax.lax.pmean(v, axes)) if axes else (lambda v: v)
    # pmean the PHYSICAL-order load first, telemetry reads it, then gather to
    # logical order — pmean commutes with the replicated-table gather, so the
    # monitor sees bitwise-identical values
    load_pm = pm(load)
    drop_pm = pm(drop)
    if dist.obs:
        n_ranks = _axes_size(dist, axes)
        imbalance = _imbalance(load_pm[:E_ns], mp, E_local)
        shadow_hits = (load_pm[E_ns:].sum() * (denom * n_ranks)
                       if E_ns < E else jnp.zeros(()))
        obs = obs_counters.reduction_counters(
            payload_elems=psum_elems, payload_dtype=psum_dtype,
            dropped=drop_pm * (denom * n_ranks),
            shadow_hits=shadow_hits, imbalance=imbalance)
    else:
        obs = ObsCounters.zero()
    if table is not None:
        load_pm = load_pm[table]  # logical order
    metrics = MoEMetrics(pm(_aux_loss(router, x, g, cfg)),
                         pm(router_z_loss(g.logits)), load_pm, drop_pm, obs)
    return y, metrics


def _moe_psum_ec(x, router, experts, extra, shadow, table, rank,
                 cfg: MoEConfig, act, expert_fn, dist: DistConfig,
                 impl: str = "einsum"):
    """Expert-choice under the psum (decode) mode.

    Tokens are replicated over the expert axis, so every rank routes the
    *global* token set identically — the (E, C) grid is the dense
    reference's, exactly.  Each rank computes only its owned expert rows of
    the grid (zeros elsewhere), partial grids psum over the expert axis
    (disjoint blocks: the reduction adds exact zeros, so the result is
    bitwise the local grid), shadowed experts are computed on every rank
    outside the reduction, and the combine scatter-adds in logical expert
    order — bitwise layout-invariant by the same argument as the slot-wise
    token-choice combine.
    """
    ax = dist.expert_axis
    mp = dist.expert_parallelism
    E = cfg.num_experts
    t, d = x.shape
    C, token_idx, ti_phys, ec_w, ec_logits = _ec_route(router, x, cfg, table)
    place = dist.placement
    E_ns = place.num_owned if place is not None else E
    E_local = E_ns // mp
    if cfg.dispatch == "ragged":
        n = E * C
        x_sorted = x[ti_phys.reshape(-1)]  # (n, d) physical-expert-major
        i = jnp.arange(n, dtype=jnp.int32)
        lo = rank * E_local * C  # my owned segment (uniform C rows/expert)
        mine = (i >= lo) & (i < lo + E_local * C)
        dest = jnp.where(mine, i - lo, n).astype(jnp.int32)  # shift to 0
        xs = jnp.zeros((n, d), x.dtype).at[dest].set(x_sorted, mode="drop")
        ys = RAGGED_FNS[impl](experts, xs,
                              jnp.full((E_local,), C, jnp.int32), act)
        y_rows = jax.lax.psum(
            ys.at[dest].get(mode="fill", fill_value=0), ax)
        psum_elems, psum_dtype = y_rows.size, y_rows.dtype
        if shadow:
            lo_sh = E_ns * C  # sorted tail = shadow rows, shifted to 0
            dest_sh = jnp.where(i >= lo_sh, i - lo_sh, n).astype(jnp.int32)
            xs_sh = jnp.zeros((n, d), x.dtype).at[dest_sh].set(x_sorted,
                                                               mode="drop")
            ys_sh = RAGGED_FNS[impl](shadow, xs_sh,
                                     jnp.full((E - E_ns,), C, jnp.int32), act)
            y_rows = y_rows + ys_sh.at[dest_sh].get(mode="fill", fill_value=0)
        out_grid = y_rows.reshape(E, C, -1)
    else:
        buf = x[ti_phys]  # (E, C, d)
        buf_local = jax.lax.dynamic_slice_in_dim(buf, rank * E_local,
                                                 E_local, axis=0)
        out_local = expert_fn(experts, buf_local, act)  # (E_local, C, dout)
        out = jax.lax.psum(jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((E_ns, C, out_local.shape[-1]), out_local.dtype),
            out_local, rank * E_local, axis=0), ax)
        psum_elems, psum_dtype = out.size, out.dtype
        if E_ns < E:
            # shadowed experts: every rank, same tokens, outside the psum
            out = jnp.concatenate([out, expert_fn(shadow, buf[E_ns:], act)],
                                  axis=0)
        out_grid = out
    out_log = out_grid if table is None else out_grid[table]
    y = D.combine_ec(out_log, token_idx, ec_w, t)
    for p in extra.values():  # see _moe_a2a
        y = y + dense_ffn(p, x, act)

    axes = tuple(dist.token_axes)
    pm = (lambda v: jax.lax.pmean(v, axes)) if axes else (lambda v: v)
    if dist.obs:
        n_ranks = _axes_size(dist, axes)
        shadow_hits = jnp.float32((E - E_ns) * C * n_ranks)
        obs = obs_counters.reduction_counters(
            payload_elems=psum_elems, payload_dtype=psum_dtype,
            dropped=jnp.zeros(()), shadow_hits=shadow_hits,
            imbalance=jnp.ones(()))
    else:
        obs = ObsCounters.zero()
    metrics = MoEMetrics(jnp.zeros(()), pm(router_z_loss(ec_logits)),
                         _ec_flat_load(E), jnp.zeros(()), obs)
    return y, metrics


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def _check_not_per_layer(place) -> None:
    """This function applies ONE layer; a stacked per-layer plan must be
    split upstream (models/lm.py) into geometry + per-layer ``l2p`` tables."""
    if place is None:
        return
    from repro.placement.plan import PerLayerPlacement
    if isinstance(place, PerLayerPlacement):
        raise TypeError(
            "fmoe_apply applies a single layer; split a PerLayerPlacement "
            "into its geometry + per-layer l2p tables (models.lm does this "
            "for the full stack) instead of passing it here")


def fmoe_apply(params: dict, x: jax.Array, cfg: MoEConfig, *, act: str = "swiglu",
               dist: Optional[DistConfig] = None, impl: str = "einsum",
               rng: Optional[jax.Array] = None, placement=None, l2p=None):
    """Apply the MoE FFN to ``x`` of shape (..., d_model).

    Returns ``(y, MoEMetrics)``.  ``impl`` selects the expert kernels
    ("einsum" | "pallas" | "fused") on every dispatch mode — capacity local,
    ragged local and the distributed paths; ``dist=None`` runs the
    single-worker §4 path, otherwise the §3.2 distributed path (mode picked
    by ``dist``).

    ``dist.placement`` is an ExpertPlacement: ``params`` must already be in
    its physical order (repro.placement.migrate); routing stays in logical
    expert space via the plan's index table.  ``dist`` is the single
    distribution-config channel — for the single-worker path pass
    ``DistConfig.local(placement=plan)`` (mesh=None carrier).  The bare
    ``placement=`` kwarg is deprecated: it warns and forwards onto ``dist``.
    ``l2p`` is *this layer's* logical->physical gate-id table (a traced (E,)
    int32 array) when the plan is per-layer: the layer scan in models/lm.py
    splits a ``PerLayerPlacement`` into the shared static geometry (riding
    on ``dist.placement``) plus the stacked tables it threads here — a
    PerLayerPlacement itself must not reach this function.
    """
    if placement is not None:
        import warnings
        warnings.warn(
            "fmoe_apply(placement=...) is deprecated; pass the plan on the "
            "dist channel instead — DistConfig.local(placement=plan) for "
            "the single-worker path, dist._replace(placement=plan) for a "
            "meshed one", DeprecationWarning, stacklevel=2)
    if dist is not None and dist.router is not None and dist.router != cfg.router:
        # the dist channel can pin the routing variant (e.g. serve-time
        # frozen routing) without touching the model config
        import dataclasses
        cfg = dataclasses.replace(cfg, router=dist.router)
    if dist is not None and dist.mesh is None:
        # DistConfig.local carrier: unwrap to the single-worker path
        if placement is None:
            placement = dist.placement
        dist = None
    expert_fn = EXPERT_FNS[impl]
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    router, experts = params["router"], params["experts"]

    residual_keys = [k for k in ("shared", "dense") if k in params]
    if dist is None:
        _check_not_per_layer(placement)
        y, metrics = _moe_local(xf, router, experts, cfg, act, expert_fn, rng,
                                placement=placement, impl=impl, l2p=l2p)
        for k in residual_keys:
            y = y + dense_ffn(params[k], xf, act)
    else:
        place = dist.placement if dist.placement is not None else placement
        if place is not None:
            _check_not_per_layer(place)
            if place.num_experts != cfg.num_experts:
                raise ValueError(
                    f"placement has {place.num_experts} experts, "
                    f"config has {cfg.num_experts}")
            if place.num_ranks != dist.expert_parallelism:
                raise ValueError(
                    f"placement built for {place.num_ranks} ranks, mesh "
                    f"expert parallelism is {dist.expert_parallelism}")
            if place.num_shadow:
                if dist.tp_axis:
                    raise NotImplementedError(
                        "expert shadowing + expert-internal TP")
                if (place.num_owned % dist.expert_parallelism
                        or place.num_owned == 0):
                    raise ValueError(
                        f"owned experts {place.num_owned} must be a positive "
                        f"multiple of {dist.expert_parallelism}")
            dist = dist._replace(placement=place)
        ragged = cfg.dispatch == "ragged"
        if ragged and dist.tp_axis:
            # the grouped ragged kernels consume flat sorted rows; the
            # capacity path's per-row tp gather/scatter doesn't apply
            raise NotImplementedError(
                "ragged dispatch + expert-internal TP (use capacity)")
        if dist.mode == "a2a":
            local = _moe_a2a_ragged if ragged else _moe_a2a
        else:
            local = _moe_psum
        tok_spec = P(dist.token_axes if dist.token_axes else None, None)

        def espec_for(path_w):
            if dist.tp_axis and dist.mode == "a2a":
                # hidden dim stays sharded (expert-internal TP, §Perf)
                if path_w == "wo":
                    return P(dist.expert_axis, dist.tp_axis, None)
                return P(dist.expert_axis, None, dist.tp_axis)
            return P(dist.expert_axis, None, None)
        espec = {k: espec_for(k) for k in experts}

        if dist.fsdp_axis and not dist.tp_axis:
            # keep the bf16 cast *sharded* so XLA gathers half the bytes
            # (otherwise the convert is hoisted after the f32-master gather)
            from jax.sharding import NamedSharding
            fspec = {k: (P(dist.expert_axis, dist.fsdp_axis, None) if k == "wo"
                         else P(dist.expert_axis, None, dist.fsdp_axis))
                     for k in experts}
            experts = {k: jax.lax.with_sharding_constraint(
                v, NamedSharding(dist.mesh, fspec[k]))
                for k, v in experts.items()}

        # shadowed hot experts: slice off the replicated tail (the broadcast
        # happens at the shard_map boundary via the P(None) in_spec)
        shadow = {}
        if dist.placement is not None and dist.placement.num_shadow:
            E_ns = dist.placement.num_owned
            shadow = {k: v[E_ns:] for k, v in experts.items()}
            experts = {k: v[:E_ns] for k, v in experts.items()}
        sspec = {k: P(None, None, None) for k in shadow}

        if dist.constrain_tokens:
            # shared/dense residual FFNs run INSIDE shard_map on local tokens
            # with replicated weights (§Perf fix — see _moe_a2a)
            extra = {k: params[k] for k in residual_keys}
            residual_keys = []
        else:
            extra = {}
        xspec = {k: jax.tree.map(lambda _: P(None, None), v)
                 for k, v in extra.items()}
        has_l2p = l2p is not None
        has_rng = rng is not None

        def fn(xf_, router_, experts_, extra_, shadow_, *rest):
            # optional trailing operands, in order: l2p table, gate rng (the
            # paths fold the rng with their token-axis indices so every
            # shard explores independently)
            _l2p = rest[0] if has_l2p else None
            _rng = rest[int(has_l2p)] if has_rng else None
            return local(xf_, router_, experts_, extra_, shadow_, _l2p,
                         cfg=cfg, act=act, expert_fn=expert_fn, dist=dist,
                         impl=impl, rng=_rng)

        mspec = MoEMetrics(P(), P(), P(None), P(),
                           ObsCounters(P(), P(), P(), P(), P(), P(), P()))
        in_specs = [tok_spec, jax.tree.map(lambda _: P(None, None), router),
                    espec, xspec, sspec]
        operands = [xf, router, experts, extra, shadow]
        if has_l2p:
            # the per-layer gate-id table rides replicated into the region
            operands.append(jnp.asarray(l2p, jnp.int32))
            in_specs.append(P(None))
        if has_rng:
            operands.append(rng)
            in_specs.append(P(None))
        y, metrics = compat.shard_map(
            fn, mesh=dist.mesh,
            in_specs=tuple(in_specs),
            out_specs=(tok_spec, mspec),
            check_vma=False,
        )(*operands)
        # paper-faithful baseline: residuals outside shard_map (auto-sharded)
        for k in residual_keys:
            y = y + dense_ffn(params[k], xf, act)
    return y.reshape(shape), metrics
