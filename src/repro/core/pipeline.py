"""Smart-schedule overlap — the paper's §5.2 pipelined global data exchange.

FastMoE's headline distributed speedup comes from partitioning the all-to-all
into groups so that sending, receiving and expert computation overlap.  The
XLA analogue: split the ``(mp, E_local, C, d)`` exchange buffer into
``n_chunks`` micro-shards along the capacity dim and emit a software-pipelined
schedule whose *dependency structure* permits overlap —

    S0 | S1  C0  R0 | S2  C1  R1 | ...  C_{n-1}  R_{n-1}

where S_i / R_i are chunk i's forward / return exchanges and C_i its expert
compute.  Chunk i+1's send is issued *before* chunk i's compute, so no
collective ever waits on the compute preceding it in program order, and XLA's
async collective scheduler can keep the ICI links and the MXU busy at the
same time.  Each exchange is further decomposed into ``mp - 1``
``ppermute``s (+ a local copy): ``collective-permute`` is the op XLA turns
into asynchronous ``-start``/``-done`` pairs, whereas a monolithic
``all-to-all`` is scheduled as one blocking step.

The schedule is *bit-exact* vs. the serial path: chunking the capacity dim
never regroups any expert's row reduction, and the decomposed exchange moves
identical bytes to identical slots.

Shadowed hot experts (repro/placement/shadow.py) slot in as overlap filler:
their local, exchange-free compute is issued right after the first send, i.e.
inside the bubble the serial schedule would spend blocked on the wire.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax.numpy as jnp
from jax import lax


_BITS_FOR_BYTES = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _to_wire(x: jnp.ndarray, orig, wire_dtype):
    """Narrow to the wire dtype AND bitcast to the same-width unsigned int.

    The bitcast is load-bearing: XLA's convert-mover hoists a plain
    ``convert`` past data-movement ops, so a bf16-cast payload would cross
    the wire as the full-width original dtype (observed on XLA:CPU) — the
    collective must *operand-type* at the wire width to actually shrink.
    A bitcast round-trip is bit-exact, so values are unchanged.
    """
    if wire_dtype is None or jnp.dtype(wire_dtype) == jnp.dtype(orig):
        return x, None
    w = x.astype(wire_dtype)
    return (lax.bitcast_convert_type(
        w, _BITS_FOR_BYTES[jnp.dtype(wire_dtype).itemsize]), wire_dtype)


def _from_wire(x: jnp.ndarray, orig, wire_dtype):
    if wire_dtype is None:
        return x.astype(orig)
    return lax.bitcast_convert_type(x, wire_dtype).astype(orig)


def ppermute_all_to_all(x: jnp.ndarray, axis, mp: int, *,
                        wire_dtype=None) -> jnp.ndarray:
    """``lax.all_to_all(x, axis, 0, 0, tiled=True)`` as mp-1 collective-permutes.

    x: (mp * n, ...) per-rank, dim 0 major-ordered by destination rank.
    ``axis`` may be a tuple of mesh axes (row-major linearization, matching
    ``lax.all_to_all``); ``mp`` is its static total size.  ``wire_dtype``
    casts the payload across the wire only (output dtype preserved).

    Shift s moves rank r's slice for rank (r+s)%mp; the receiver q writes it
    at slot (q-s)%mp — exactly where all_to_all concatenates the data coming
    from rank (q-s)%mp.  Each shift is an independent collective-permute, so
    XLA may issue all of them (and overlap them with unrelated compute)
    instead of scheduling one blocking fused all-to-all.
    """
    orig = x.dtype
    x, wd = _to_wire(x, orig, wire_dtype)
    if mp == 1:
        return _from_wire(x, orig, wd)
    n = x.shape[0] // mp
    idx = lax.axis_index(axis)
    own = lax.dynamic_slice_in_dim(x, idx * n, n, 0)
    out = lax.dynamic_update_slice_in_dim(jnp.zeros_like(x), own, idx * n, 0)
    for s in range(1, mp):
        send = lax.dynamic_slice_in_dim(x, ((idx + s) % mp) * n, n, 0)
        recv = lax.ppermute(send, axis,
                            [(r, (r + s) % mp) for r in range(mp)])
        out = lax.dynamic_update_slice_in_dim(out, recv,
                                              ((idx - s) % mp) * n, 0)
    return _from_wire(out, orig, wd)


def chunked_all_to_all(x: jnp.ndarray, axis, mp: int, n_chunks: int, *,
                       wire_dtype=None, decompose: bool = True) -> jnp.ndarray:
    """Tiled dim-0 all-to-all split into ``n_chunks`` independent exchanges.

    x: (mp, ...) per-rank (one slice per destination).  The chunk dim is
    x.shape[1], which must divide by ``n_chunks``.  Pure data movement —
    bit-exact vs. the monolithic collective for any chunking.
    """
    a2a = functools.partial(
        ppermute_all_to_all if decompose else _plain_all_to_all,
        axis=axis, mp=mp, wire_dtype=wire_dtype)
    if n_chunks <= 1:
        return a2a(x)
    return jnp.concatenate([a2a(c) for c in jnp.split(x, n_chunks, axis=1)],
                           axis=1)


def _plain_all_to_all(x, *, axis, mp, wire_dtype=None):
    del mp
    orig = x.dtype
    x, wd = _to_wire(x, orig, wire_dtype)
    return _from_wire(lax.all_to_all(x, axis, 0, 0, tiled=True), orig, wd)


def counts_all_to_all(counts: jnp.ndarray, axis, mp: int, *,
                      decompose: bool) -> jnp.ndarray:
    """The Fig-2 "exchange sizes" step: (mp, E_local) per-destination counts
    -> (mp, E_local) per-source counts.  ``decompose`` swaps the blocking
    all-to-all for mp-1 collective-permutes so the pipelined schedules'
    HLO contains no blocking exchange at all (capacity and ragged paths
    share this helper — their wire behavior must not drift apart)."""
    if decompose:
        return ppermute_all_to_all(counts, axis, mp)
    return lax.all_to_all(counts, axis, 0, 0, tiled=True)


def wire_fraction(mp: int, *, decompose: bool) -> float:
    """Fraction of a tiled dim-0 exchange that actually crosses the wire.

    The ppermute decomposition keeps each rank's own slice on-chip (only the
    mp-1 shifted slices move), so decomposed exchanges transfer (mp-1)/mp of
    the nominal buffer — which is also exactly what the optimized HLO's
    collective-permute output bytes sum to, keeping the device-side wire
    counters (repro.obs.counters) 1:1 comparable with
    ``roofline.collective_bytes``.  A monolithic all-to-all is accounted at
    its full output size, matching its HLO op.
    """
    return (mp - 1) / mp if (decompose and mp > 0) else 1.0


def resolve_chunks(requested: int, capacity: int) -> int:
    """Largest divisor of ``capacity`` that is <= ``requested`` (>= 1).

    The micro-shard split must tile the static capacity exactly; rather than
    failing on awkward (capacity, n_chunks) pairs, degrade to the nearest
    feasible pipeline depth (1 = serial).
    """
    n = max(1, min(int(requested), int(capacity)))
    while capacity % n:
        n -= 1
    return n


def ragged_pipelined_exchange(send: jnp.ndarray, axis, mp: int, n_chunks: int,
                              *, fill_fn: Optional[Callable[[], jnp.ndarray]] = None,
                              wire_dtype=None):
    """Forward half of the ragged (dropless) exchange, micro-sharded.

    send: (mp, bound, d) pad-to-max-per-peer shards (core/dispatch
    make_ragged_xplan layout).  With ``n_chunks > 1`` the bound dim splits
    into ppermute-decomposed micro-shards — every exchange is an
    async-schedulable collective-permute, none a blocking all-to-all — and
    ``fill_fn`` (the shadowed experts' local, exchange-free compute) issues
    in the first chunk's wire bubble, exactly like the capacity schedule's
    shadow filler.  Unlike :func:`pipelined_expert_exchange` the expert
    compute itself is NOT interleaved per chunk: the grouped kernels need
    the compacted expert-sorted rows, which exist only after every shard
    lands (ROADMAP follow-on).  Returns ``(recv, fill_out | None)``.
    """
    decompose = n_chunks > 1
    a2a = functools.partial(
        ppermute_all_to_all if decompose else _plain_all_to_all,
        axis=axis, mp=mp, wire_dtype=wire_dtype)
    if n_chunks <= 1:
        recv = a2a(send)
        return recv, (fill_fn() if fill_fn is not None else None)
    chunks = jnp.split(send, n_chunks, axis=1)
    recvs = [a2a(chunks[0])]
    fill_out = fill_fn() if fill_fn is not None else None  # S0 bubble
    recvs += [a2a(c) for c in chunks[1:]]
    return jnp.concatenate(recvs, axis=1), fill_out


def all_to_all_dim1(x: jnp.ndarray, axis, mp: int, *, decompose: bool = False,
                    wire_dtype=None) -> jnp.ndarray:
    """Tiled all-to-all splitting/concatenating on dim 1 (dim1 size == mp).

    The intra-node hop of the two-level ragged exchange: buffers are laid out
    ``(n_nodes, n_inner, ...)`` and the node-local exchange moves dim 1 while
    dim 0 (destination node) stays put.  Implemented as a transpose around
    the dim-0 helpers so the ppermute decomposition and wire-dtype bitcast
    behave identically to every other exchange in this module.
    """
    perm = (1, 0) + tuple(range(2, x.ndim))
    fn = ppermute_all_to_all if decompose else _plain_all_to_all
    return fn(x.transpose(perm), axis=axis, mp=mp,
              wire_dtype=wire_dtype).transpose(perm)


def hier_ragged_pipeline(send: jnp.ndarray, axis, mp: int, n_chunks: int,
                         chunk_fn: Callable[[jnp.ndarray, int], jnp.ndarray],
                         *, fill_fn: Optional[Callable[[], jnp.ndarray]] = None,
                         wire_dtype=None):
    """Inter-node leg of the two-level ragged exchange, with per-chunk compute.

    send: (mp, inter_bound, d) slim per-node shards (mp = n_nodes here).
    ``chunk_fn(recv_chunk, c) -> out_chunk`` runs the expert compute on chunk
    ``c``'s received rows — (mp, w, d) -> (mp, w, d_out) with
    ``w = inter_bound // n_chunks`` — using its own mini-compaction
    (core/dispatch.hier_chunk_plans).  The §5.2 smart schedule applies to
    this leg alone: S_{c+1} is issued before C_c and R_c right after, so at
    steady state one send, one grouped-GEMM and one receive are in flight —
    unlike the flat ragged path, the hierarchical receiver CAN compute per
    chunk, because each chunk's counts are known before its payload lands.
    ``fill_fn`` (shadowed experts) issues in S0's wire bubble.  Returns
    ``(ret (mp, inter_bound, d_out), fill_out | None)``.
    """
    decompose = n_chunks > 1
    a2a = functools.partial(
        ppermute_all_to_all if decompose else _plain_all_to_all,
        axis=axis, mp=mp, wire_dtype=wire_dtype)
    if n_chunks <= 1:
        recv = a2a(send)
        fill_out = fill_fn() if fill_fn is not None else None
        return a2a(chunk_fn(recv, 0)), fill_out
    chunks = jnp.split(send, n_chunks, axis=1)
    recv: list = [None] * n_chunks
    outs: list = [None] * n_chunks
    fill_out = None
    recv[0] = a2a(chunks[0])  # S0: warm the pipeline
    for c in range(n_chunks):
        if c + 1 < n_chunks:
            recv[c + 1] = a2a(chunks[c + 1])  # S_{c+1} before C_c
        if c == 0 and fill_fn is not None:
            fill_out = fill_fn()  # shadow compute fills the S0 bubble
        outs[c] = a2a(chunk_fn(recv[c], c))  # C_c then R_c
    return jnp.concatenate(outs, axis=1), fill_out


def pipelined_expert_exchange(
        buf: jnp.ndarray, axis, mp: int, n_chunks: int,
        compute_fn: Callable[[jnp.ndarray], jnp.ndarray], *,
        fill_fn: Optional[Callable[[], jnp.ndarray]] = None,
        wire_dtype=None, decompose: bool = True):
    """Dispatch a2a -> expert compute -> return a2a, software-pipelined.

    buf: (mp, E_local, C, d) dispatch buffer (dim 0 = destination rank).
    compute_fn: (E_local, rows, d) -> (E_local, rows, d_out) row-independent
    expert computation (the caller wraps any tp_axis gather/scatter).
    fill_fn: optional exchange-free local work (shadowed experts) issued in
    the first chunk's wire bubble; its result is returned alongside.

    Returns (out: (mp, E_local, C, d_out), fill_out | None).

    The schedule is the paper's Fig-6 smart schedule: chunk i+1's forward
    exchange is issued before chunk i's compute, and chunk i's return
    exchange right after it, so at steady state one send, one compute and
    one receive are always in flight together.
    """
    mp_, E_local, C, d = buf.shape
    assert mp_ == mp and C % n_chunks == 0, (buf.shape, mp, n_chunks)
    a2a = functools.partial(
        ppermute_all_to_all if decompose else _plain_all_to_all,
        axis=axis, mp=mp, wire_dtype=wire_dtype)

    if n_chunks <= 1:
        recv = a2a(buf)
        fill_out = fill_fn() if fill_fn is not None else None
        y = compute_fn(recv.transpose(1, 0, 2, 3).reshape(E_local, mp * C, d))
        y = y.reshape(E_local, mp, C, -1).transpose(1, 0, 2, 3)
        return a2a(y), fill_out

    Cc = C // n_chunks
    chunks = jnp.split(buf, n_chunks, axis=2)
    recv: list = [None] * n_chunks
    outs: list = [None] * n_chunks
    fill_out = None
    recv[0] = a2a(chunks[0])  # S0: warm the pipeline
    for i in range(n_chunks):
        if i + 1 < n_chunks:
            recv[i + 1] = a2a(chunks[i + 1])  # S_{i+1} before C_i
        if i == 0 and fill_fn is not None:
            fill_out = fill_fn()  # shadow compute fills the S0 bubble
        x = recv[i].transpose(1, 0, 2, 3).reshape(E_local, mp * Cc, d)
        y = compute_fn(x)  # C_i
        y = y.reshape(E_local, mp, Cc, -1).transpose(1, 0, 2, 3)
        outs[i] = a2a(y)  # R_i
    return jnp.concatenate(outs, axis=2), fill_out
