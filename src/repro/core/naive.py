"""Naive MoE baselines the paper compares against (§5.2, Fig 5).

The Rau (2019) baseline computes experts without batching tokens per expert.
Two JAX renditions of that inefficiency (both numerically equivalent to
:func:`repro.core.fmoe.fmoe_apply`):

* ``loop_masked`` — python loop over experts; every expert processes ALL
  tokens densely, outputs masked by the gate.  O(E) full-batch GeMMs.
* ``per_sample`` — vmap over tokens; each token gathers its k experts'
  weights and does GeMV-shaped matvecs (the degenerate GeMM of paper Fig 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.fmoe import _act
from repro.core.gate import gate_forward


def _one_expert(experts: dict, e, x: jax.Array, act: str) -> jax.Array:
    """Apply expert ``e`` (static or traced index) to tokens (..., d)."""
    take = lambda w: w[e]
    if act == "swiglu":
        h = jax.nn.silu(x @ take(experts["wi_gate"])) * (x @ take(experts["wi_up"]))
    else:
        h = _act(x @ take(experts["wi"]), act)
    return h @ take(experts["wo"])


def moe_loop_masked(params: dict, x: jax.Array, cfg: MoEConfig, *,
                    act: str = "swiglu") -> jax.Array:
    """Every expert computes every token; gate mask zeroes the rest."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    g = gate_forward(params["router"], xf, cfg)
    y = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        out = _one_expert(params["experts"], e, xf, act)
        w = jnp.where(g.expert_ids == e, g.combine_weights, 0.0).sum(-1)
        y = y + out * w[:, None].astype(out.dtype)
    return y.reshape(shape)


def moe_per_sample(params: dict, x: jax.Array, cfg: MoEConfig, *,
                   act: str = "swiglu") -> jax.Array:
    """Per-token expert gather + GeMV — the batch-size-1 regime of Fig 3."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    g = gate_forward(params["router"], xf, cfg)

    def token_fn(tok, eids, ws):
        def slot(eid, w):
            return w.astype(tok.dtype) * _one_expert(params["experts"], eid, tok, act)
        return jax.vmap(slot)(eids, ws).sum(0)

    y = jax.vmap(token_fn)(xf, g.expert_ids, g.combine_weights)
    return y.reshape(shape)
