"""Heterogeneity-aware gradient synchronization (paper §3.2).

FastMoE tags every parameter ``world`` / ``data parallel`` / ``none`` and runs
a custom DDP that all-reduces each gradient within the right group.  Under
pjit, gradient synchronization *is* the sharding spec: a parameter replicated
over a mesh axis gets its gradient all-reduced over that axis automatically
by the SPMD partitioner.  This module makes the correspondence explicit — it
derives the FastMoE tag from a parameter's PartitionSpec and verifies the
rule table realizes the paper's semantics (tested in tests/test_sync.py).
"""
from __future__ import annotations

from typing import Sequence

from jax.sharding import PartitionSpec


def spec_axes(spec: PartitionSpec) -> set:
    """Mesh axes a PartitionSpec shards over."""
    axes: set = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            axes.update(entry)
        else:
            axes.add(entry)
    return axes


def grad_sync_axes(spec: PartitionSpec, mesh_axes: Sequence[str]) -> tuple:
    """Mesh axes over which this parameter's gradient is implicitly
    all-reduced by XLA = the axes the parameter is *replicated* over."""
    used = spec_axes(spec)
    return tuple(a for a in mesh_axes if a not in used)


def fastmoe_tag(path: str, spec: PartitionSpec, mesh_axes: Sequence[str], *,
                expert_axis: str = "model",
                data_axes: tuple = ("pod", "data")) -> str:
    """Map a parameter to the paper's sync tag.

    * ``world``  — replicated on every axis (gate/router, norms): gradient
      all-reduced across all workers.
    * ``dp``     — sharded over the model axis (TP attention / FFN shards):
      synchronized only within the data-parallel group orthogonal to model.
    * ``none``   — unique expert parameters: sharded over the expert axis on
      their expert dimension; no synchronization across expert peers.  (On a
      mesh with a data axis the expert is still replicated across data
      replicas, so its gradient syncs over ``data`` — the paper's pure
      model-parallel deployment is the data=1 special case.)
    """
    used = spec_axes(spec)
    model_like = used - set(data_axes)
    if not model_like:
        return "world"
    is_expert = ("expert" in path) or ("router" not in path and path.startswith("moe"))
    if expert_axis in model_like and is_expert:
        return "none"
    return "dp"


def sync_report(specs: dict, mesh_axes: Sequence[str]) -> dict:
    """{param_path: (tag, sync_axes)} for the whole param tree (flat paths)."""
    report = {}
    for path, spec in specs.items():
        report[path] = (fastmoe_tag(path, spec, mesh_axes),
                        grad_sync_axes(spec, mesh_axes))
    return report
