"""Load-balance monitor (paper §6: "The work of load-balance monitor ... is
in progress") — a host-side tracker fed by the MoEMetrics every step.

Tracks per-expert load EMAs, drop rates, and imbalance statistics, and can
emit CSV/JSON for dashboards.  The distributed a2a path feeds it from the
Fig-2 counts exchange (see repro.core.fmoe._moe_a2a), so the monitored load
is the *global* per-expert arrival count, not a local estimate.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Optional

import numpy as np


class LoadMonitor:
    def __init__(self, num_experts: int, *, ema: float = 0.99,
                 num_layers: int = 0, history_cap: int = 512,
                 record_every: int = 0, sink=None):
        self.num_experts = num_experts
        self.ema = ema
        self.load_ema = np.full(num_experts, 1.0 / num_experts)
        # per-layer mode (num_layers > 0): additionally track an (L, E) EMA —
        # expert skew diverges per layer in deep stacks, and the per-layer
        # planner (repro.placement.plan.plan_placement_per_layer) feeds on it
        self.num_layers = num_layers
        self.load_ema_layers = (np.full((num_layers, num_experts),
                                        1.0 / num_experts)
                                if num_layers else None)
        self.drop_ema = 0.0
        self.steps = 0
        # resilience latch (repro.resilience.guard): once the step guard has
        # forced the dropless fallback, adaptive bound suggestions must not
        # re-shrink the shards at the next replan re-jit — the spike already
        # proved the EMAs untrustworthy for sizing
        self.force_dropless = False
        # bounded ring: long runs must not grow host memory without limit
        self.history: deque = deque(maxlen=max(1, int(history_cap)))
        self.record_every = record_every  # default cadence for update()
        self.sink = sink  # optional repro.obs.sink.MetricsSink

    def update(self, metrics, *, record_every: Optional[int] = None) -> None:
        """metrics: repro.core.balance.MoEMetrics.  ``metrics.load`` may be
        an (E,) vector (summed over layers; renormalized here) or an (L, E)
        per-layer stack — the latter also refreshes ``load_ema_layers``.
        ``record_every`` overrides the instance default for this call; each
        recorded snapshot also lands in the attached sink."""
        load = np.asarray(metrics.load, np.float64)
        if load.ndim == 2:
            if self.load_ema_layers is not None:
                if load.shape != self.load_ema_layers.shape:
                    raise ValueError(
                        f"layer load {load.shape} != "
                        f"{self.load_ema_layers.shape}")
                rows = load / np.maximum(load.sum(-1, keepdims=True), 1e-12)
                self.load_ema_layers = (self.ema * self.load_ema_layers
                                        + (1 - self.ema) * rows)
            load = load.sum(0)
        total = load.sum()
        if total > 0:
            load = load / total
        drop = float(np.asarray(metrics.drop_frac))
        self.load_ema = self.ema * self.load_ema + (1 - self.ema) * load
        self.drop_ema = self.ema * self.drop_ema + (1 - self.ema) * drop
        self.steps += 1
        if record_every is None:
            record_every = self.record_every
        if record_every and self.steps % record_every == 0:
            rec = {"step": self.steps, **self.snapshot()}
            self.history.append(rec)
            if self.sink is not None:
                self.sink.emit({"kind": "load_monitor", **rec})

    def snapshot(self) -> dict:
        l = self.load_ema / max(self.load_ema.sum(), 1e-12)
        uniform = 1.0 / self.num_experts
        return {
            "max_load": float(l.max()),
            "min_load": float(l.min()),
            "imbalance": float(l.max() / uniform),  # 1.0 == perfectly balanced
            "cv": float(l.std() / max(l.mean(), 1e-12)),
            "drop_ema": float(self.drop_ema),
        }

    @property
    def imbalance(self) -> float:
        return self.snapshot()["imbalance"]

    def suggest_ragged_bound(self, num_tokens_local: int, top_k: int,
                             num_peers: int, *, headroom: float = 1.25,
                             multiple: int = 8,
                             drop_guard: float = 1e-3) -> int:
        """Adaptive bound for the ragged exchange's per-peer shards.

        The dropless default (``T_local * k``) sizes every shard for the
        worst case — all local assignments landing on one peer.  The EMAs
        already know the *actual* peak peer share (experts partition into
        ``num_peers`` contiguous physical blocks), so size the shard to
        peak share × ``headroom`` instead and let wire bytes shrink with
        measured load.  Guard rails: an un-warmed monitor (``steps == 0``)
        or a drop EMA above ``drop_guard`` — evidence the current bounds
        are already clipping — falls back to the never-drop bound; results
        round up to ``multiple`` (lane-friendly) and clamp to [multiple, n].
        """
        n = int(num_tokens_local) * int(top_k)
        e_pp = self.num_experts // max(1, int(num_peers))
        if (self.force_dropless or self.steps == 0 or e_pp == 0
                or float(self.drop_ema) > drop_guard):
            return n
        l = self.load_ema / max(self.load_ema.sum(), 1e-12)
        peak = max(float(l[p * e_pp:(p + 1) * e_pp].sum())
                   for p in range(int(num_peers)))
        bound = int(np.ceil(n * peak * headroom))
        bound = -(-bound // multiple) * multiple  # round up to multiple
        return int(min(max(bound, multiple), n))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"num_experts": self.num_experts, "steps": self.steps,
                       "final": self.snapshot(),
                       "history": list(self.history)}, f, indent=1)


def expert_placement(num_experts: int, num_workers: int,
                     load: Optional[np.ndarray] = None) -> list:
    """Greedy load-aware expert->worker placement (beyond-paper): given a
    measured per-expert load, balance the sum of loads per worker instead of
    FastMoE's contiguous blocks.  Returns worker id per expert.

    When ``num_experts % num_workers != 0`` the remainder is spread one extra
    expert per worker (caps differ by at most 1), so every expert is placed.
    """
    if load is None:
        return [e * num_workers // num_experts for e in range(num_experts)]
    order = np.argsort(-np.asarray(load, np.float64))
    totals = np.zeros(num_workers)
    counts = np.zeros(num_workers, np.int64)
    base, rem = divmod(num_experts, num_workers)
    caps = np.full(num_workers, base, np.int64)
    caps[:rem] += 1
    place = np.zeros(num_experts, np.int64)
    for e in order:
        # lightest worker with remaining capacity (caps within +-1 of E/W)
        for w in np.argsort(totals, kind="stable"):
            if counts[w] < caps[w]:
                place[e] = w
                totals[w] += load[e]
                counts[w] += 1
                break
    return place.tolist()
