"""``fmoefy`` — the paper's §3.1 Megatron-LM plugin, as a config rewrite.

FastMoE's ``fmoefy(model, num_experts)`` monkey-patches the FFN of every
transformer layer into an MoE.  JAX models here are interpreted from configs,
so the plugin is a pure function ModelConfig -> ModelConfig.  Following the
paper's §5.4 methodology, the expert hidden width defaults to d_ff / top_k so
the *active* FLOPs match the dense original.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig


def fmoefy(cfg: ModelConfig, num_experts: int = 96, top_k: int = 2, *,
           d_expert_hidden: int | None = None,
           capacity_factor: float = 1.25,
           keep_active_flops: bool = True) -> ModelConfig:
    """Replace the dense FFN of ``cfg`` with an MoE FFN (paper Listing 1)."""
    if cfg.moe is not None:
        raise ValueError(f"{cfg.name} already has an MoE FFN")
    if d_expert_hidden is None:
        d_expert_hidden = max(8, cfg.d_ff // top_k) if keep_active_flops else cfg.d_ff
    moe = MoEConfig(num_experts=num_experts, top_k=top_k,
                    d_expert_hidden=d_expert_hidden,
                    capacity_factor=capacity_factor)
    family = cfg.family if cfg.family in ("audio", "vlm", "ssm", "hybrid") else "moe"
    return dataclasses.replace(
        cfg, name=f"{cfg.name}-moe{num_experts}", moe=moe, family=family)
