"""Load-balance losses and monitoring (paper §6 lists these as future work —
implemented here as a beyond-paper feature, following Switch/GShard)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.obs.counters import ObsCounters


class MoEMetrics(NamedTuple):
    """Per-MoE-layer metrics, accumulable across layers (all arrays).

    ``obs`` carries the device-side telemetry counters (repro.obs.counters)
    through the same layer-scan accumulation; monitor-feeding constructions
    that never '+'-accumulate may leave it None (the default).
    """

    aux_loss: jax.Array  # scalar — Switch load-balance loss
    z_loss: jax.Array  # scalar — router logit z-loss
    load: jax.Array  # (E,) float32 — fraction of tokens assigned per expert
    drop_frac: jax.Array  # scalar — fraction of (token, slot) pairs dropped
    obs: Any = None  # Optional[ObsCounters] — wire/drop/shadow counters

    @staticmethod
    def zero(num_experts: int) -> "MoEMetrics":
        z = jnp.zeros(())
        return MoEMetrics(z, z, jnp.zeros((num_experts,)), z,
                          ObsCounters.zero())

    def __add__(self, other: "MoEMetrics") -> "MoEMetrics":
        return MoEMetrics(*(b if a is None else a if b is None else a + b
                            for a, b in zip(self, other)))


def load_balance_loss(probs: jax.Array, expert_ids: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch-Transformer aux loss: E * sum_e f_e * P_e.

    f_e = fraction of tokens whose top-1 choice is e; P_e = mean router prob.
    Minimized (=1) at uniform routing.
    """
    top1 = expert_ids[:, 0]
    f = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=probs.dtype), axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def router_z_loss(logits: jax.Array) -> jax.Array:
    """ST-MoE z-loss: mean(logsumexp(logits)^2) — keeps router logits small."""
    return jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)


def load_metrics(load_counts: jax.Array, keep: jax.Array | None,
                 num_assignments: jax.Array | int) -> tuple[jax.Array, jax.Array]:
    """(normalized per-expert load, dropped fraction) — the paper's §6
    'load-balance monitor'."""
    total = jnp.maximum(jnp.asarray(num_assignments, jnp.float32), 1.0)
    load = load_counts.astype(jnp.float32) / total
    if keep is None:
        drop = jnp.zeros(())
    else:
        drop = 1.0 - jnp.sum(keep.astype(jnp.float32)) / total
    return load, drop
