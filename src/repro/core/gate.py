"""Top-k gate networks (paper §2.1, Algorithm 1).

The gate scores every expert for every token and selects the top-k.  FastMoE
lets users swap the gate; we support the two standard score policies and keep
the router in float32 (routing decisions are precision-sensitive).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


class GateOutput(NamedTuple):
    """Routing decision for a flat batch of T tokens."""

    expert_ids: jax.Array  # (T, k) int32 — selected expert per slot
    combine_weights: jax.Array  # (T, k) float32 — mixing weight per slot
    probs: jax.Array  # (T, E) float32 — full router distribution (for aux losses)
    logits: jax.Array  # (T, E) float32 (for z-loss)


def gate_init(rng: jax.Array, d_model: int, num_experts: int,
              dtype=jnp.float32) -> dict:
    scale = d_model ** -0.5
    return {"w": (jax.random.normal(rng, (d_model, num_experts)) * scale).astype(dtype)}


def gate_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                 rng: jax.Array | None = None) -> GateOutput:
    """Score and select experts for flat tokens ``x`` of shape (T, d)."""
    router_dtype = jnp.dtype(cfg.router_dtype)
    logits = jnp.asarray(x, router_dtype) @ jnp.asarray(params["w"], router_dtype)
    if rng is not None:  # optional exploration jitter (train-time)
        logits = logits + jax.random.normal(rng, logits.shape, router_dtype) * 0.01
    probs = jax.nn.softmax(logits, axis=-1)

    k = cfg.top_k
    if cfg.gate_policy == "softmax_topk":
        weights, expert_ids = jax.lax.top_k(probs, k)
    elif cfg.gate_policy == "topk_softmax":
        top_logits, expert_ids = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(top_logits, axis=-1)
    else:
        raise ValueError(f"unknown gate_policy {cfg.gate_policy!r}")

    if cfg.renormalize:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return GateOutput(expert_ids.astype(jnp.int32), weights.astype(router_dtype),
                      probs, logits)


# ---------------------------------------------------------------------------
# Gate variants (paper §3.1: the gate is user-swappable)
# ---------------------------------------------------------------------------


def noisy_topk_init(rng: jax.Array, d_model: int, num_experts: int) -> dict:
    """Shazeer et al. 2017 noisy top-k gate — the original gate of the MoE
    line FastMoE implements.  Learned per-expert noise scale."""
    k1, k2 = jax.random.split(rng)
    scale = d_model ** -0.5
    return {"w": jax.random.normal(k1, (d_model, num_experts)) * scale,
            "w_noise": jax.random.normal(k2, (d_model, num_experts)) * scale * 0.1}


def router_init(rng: jax.Array, d_model: int, cfg: MoEConfig,
                dtype=jnp.float32) -> dict:
    """Router params for ``cfg.router``.

    Every variant carries ``w`` (the live gate).  ``noisy_topk`` adds
    ``w_noise``; the exploration routers (``noisy_topk``/``gumbel``) and
    ``frozen`` also carry ``w_frozen`` — the StableMoE-style lightweight
    router the live gate distills into during stage 1, so switching
    ``router`` to "frozen" mid-run (launch/train ``--freeze_router_at``) is
    a pure config change with no param-tree surgery.

    The rng is split ONLY for variants that draw extra params: the default
    ``topk`` (and ``expert_choice``) stream must stay bit-identical to the
    pre-zoo ``gate_init(rng, ...)`` — seeds, checkpoints, and every
    routing-sensitive differential test depend on it.
    """
    if cfg.router not in ("noisy_topk", "gumbel", "frozen"):
        return gate_init(rng, d_model, cfg.num_experts, dtype=dtype)
    k1, k2 = jax.random.split(rng)
    if cfg.router == "noisy_topk":
        p = noisy_topk_init(k1, d_model, cfg.num_experts)
        p = {k: v.astype(dtype) for k, v in p.items()}
    else:
        p = gate_init(k1, d_model, cfg.num_experts, dtype=dtype)
    scale = d_model ** -0.5
    p["w_frozen"] = (jax.random.normal(k2, (d_model, cfg.num_experts))
                     * scale).astype(dtype)
    return p


def gumbel_topk_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                        rng: jax.Array | None = None) -> GateOutput:
    """Gumbel-perturbed top-k (StableMoE-style exploration): selection runs
    on ``logits + temperature * Gumbel(0,1)`` while combine weights stay the
    *clean* softmax probabilities gathered at the selected ids (renormalized)
    — noise explores the assignment, not the mixture.  With ``rng=None`` or
    temperature 0 this is exactly the deterministic softmax top-k gate."""
    router_dtype = jnp.dtype(cfg.router_dtype)
    logits = (jnp.asarray(x, router_dtype)
              @ jnp.asarray(params["w"], router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    sel = logits
    if rng is not None and cfg.router_temperature > 0:
        u = jax.random.uniform(rng, logits.shape, router_dtype,
                               minval=jnp.finfo(router_dtype).tiny, maxval=1.0)
        sel = logits + cfg.router_temperature * -jnp.log(-jnp.log(u))
    _, expert_ids = jax.lax.top_k(sel, cfg.top_k)
    weights = jnp.take_along_axis(probs, expert_ids, axis=-1)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return GateOutput(expert_ids.astype(jnp.int32),
                      weights.astype(router_dtype), probs, logits)


def frozen_forward(params: dict, x: jax.Array, cfg: MoEConfig) -> GateOutput:
    """StableMoE stage 2: score through the frozen distilled router.

    ``w_frozen`` is stop-gradiented, so the routing *strategy* never moves
    again — gate-id tables are stable, and placement replans become pure
    load responses.  Combine weights still read the frozen scores (softmax
    over the selected k), so gradients keep flowing to the token
    representations through the mixture."""
    wf = jax.lax.stop_gradient(jnp.asarray(params["w_frozen"], jnp.float32))
    logits = jnp.asarray(x, jnp.float32) @ wf
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, expert_ids = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top_logits, axis=-1)
    return GateOutput(expert_ids.astype(jnp.int32), weights, probs, logits)


def route_tokens(params: dict, x: jax.Array, cfg: MoEConfig, *,
                 rng: jax.Array | None = None) -> GateOutput:
    """Dispatch to the token-choice router selected by ``cfg.router``.

    Expert-choice is not a token-choice gate (it emits an (E, C) token grid,
    not (T, k) expert ids) — the MoE paths branch on it before calling here.
    """
    if cfg.router == "topk":
        return gate_forward(params, x, cfg, rng=rng)
    if cfg.router == "noisy_topk":
        return noisy_topk_forward(params, x, cfg, rng=rng)
    if cfg.router == "gumbel":
        return gumbel_topk_forward(params, x, cfg, rng=rng)
    if cfg.router == "frozen":
        return frozen_forward(params, x, cfg)
    raise ValueError(f"unknown router {cfg.router!r}")


def router_distill_loss(params: dict, x: jax.Array, g: GateOutput) -> jax.Array:
    """StableMoE stage-1 distillation: cross-entropy of the lightweight
    frozen-router-to-be against the live gate's top-1 assignment.  Gradients
    reach only ``w_frozen`` (inputs and targets are stop-gradiented), so the
    distillation rides the aux-loss channel without perturbing the live
    gate."""
    xf = jax.lax.stop_gradient(jnp.asarray(x, jnp.float32))
    logits = xf @ jnp.asarray(params["w_frozen"], jnp.float32)
    target = jax.lax.stop_gradient(g.expert_ids[:, 0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, target[:, None], axis=-1).mean()


def noisy_topk_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                       rng: jax.Array | None = None) -> GateOutput:
    """H(x) = x.W + eps * softplus(x.W_noise); top-k over H (train-time noise
    encourages exploration; deterministic when rng is None)."""
    xf = jnp.asarray(x, jnp.float32)
    clean = xf @ jnp.asarray(params["w"], jnp.float32)
    logits = clean
    if rng is not None:
        noise_scale = jax.nn.softplus(
            xf @ jnp.asarray(params["w_noise"], jnp.float32))
        logits = clean + jax.random.normal(rng, clean.shape) * noise_scale
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, expert_ids = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top_logits, axis=-1)
    return GateOutput(expert_ids.astype(jnp.int32), weights, probs, logits)


def expert_choice_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                          capacity: int) -> tuple:
    """Expert-choice routing (Zhou et al. 2022, beyond-paper): each EXPERT
    picks its top-``capacity`` tokens instead of tokens picking experts —
    perfectly load-balanced by construction (no aux loss, no drops beyond
    the capacity itself).

    Returns (token_idx (E, C) int32, weights (E, C) f32, probs (T, E),
    logits (T, E)).
    """
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(params["w"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    # scores transposed: experts choose tokens
    weights, token_idx = jax.lax.top_k(probs.T, capacity)  # (E, C)
    return token_idx.astype(jnp.int32), weights, probs, logits


def expert_choice_moe(params: dict, x: jax.Array, cfg: MoEConfig, *,
                      act: str = "swiglu", capacity_factor: float = 2.0):
    """Full expert-choice MoE layer (gather by expert choice, FFN, scatter-add
    back weighted).  Single-worker reference implementation — the dispatched
    expert-choice paths in core/fmoe must match it (differentially tested)."""
    from repro.core import dispatch as D
    from repro.core.fmoe import expert_ffn

    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    E = cfg.num_experts
    C = D.ec_capacity(T, E, capacity_factor)
    token_idx, weights, probs, _ = expert_choice_forward(
        params["router"], xf, cfg, capacity=C)
    bufs = xf[token_idx]  # (E, C, d)
    out = expert_ffn(params["experts"], bufs, act)
    y = D.combine_ec(out, token_idx, weights, T).astype(xf.dtype)
    return y.reshape(shape), probs
