"""Top-k gate networks (paper §2.1, Algorithm 1).

The gate scores every expert for every token and selects the top-k.  FastMoE
lets users swap the gate; we support the two standard score policies and keep
the router in float32 (routing decisions are precision-sensitive).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


class GateOutput(NamedTuple):
    """Routing decision for a flat batch of T tokens."""

    expert_ids: jax.Array  # (T, k) int32 — selected expert per slot
    combine_weights: jax.Array  # (T, k) float32 — mixing weight per slot
    probs: jax.Array  # (T, E) float32 — full router distribution (for aux losses)
    logits: jax.Array  # (T, E) float32 (for z-loss)


def gate_init(rng: jax.Array, d_model: int, num_experts: int,
              dtype=jnp.float32) -> dict:
    scale = d_model ** -0.5
    return {"w": (jax.random.normal(rng, (d_model, num_experts)) * scale).astype(dtype)}


def gate_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                 rng: jax.Array | None = None) -> GateOutput:
    """Score and select experts for flat tokens ``x`` of shape (T, d)."""
    router_dtype = jnp.dtype(cfg.router_dtype)
    logits = jnp.asarray(x, router_dtype) @ jnp.asarray(params["w"], router_dtype)
    if rng is not None:  # optional exploration jitter (train-time)
        logits = logits + jax.random.normal(rng, logits.shape, router_dtype) * 0.01
    probs = jax.nn.softmax(logits, axis=-1)

    k = cfg.top_k
    if cfg.gate_policy == "softmax_topk":
        weights, expert_ids = jax.lax.top_k(probs, k)
    elif cfg.gate_policy == "topk_softmax":
        top_logits, expert_ids = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(top_logits, axis=-1)
    else:
        raise ValueError(f"unknown gate_policy {cfg.gate_policy!r}")

    if cfg.renormalize:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return GateOutput(expert_ids.astype(jnp.int32), weights.astype(router_dtype),
                      probs, logits)


# ---------------------------------------------------------------------------
# Gate variants (paper §3.1: the gate is user-swappable)
# ---------------------------------------------------------------------------


def noisy_topk_init(rng: jax.Array, d_model: int, num_experts: int) -> dict:
    """Shazeer et al. 2017 noisy top-k gate — the original gate of the MoE
    line FastMoE implements.  Learned per-expert noise scale."""
    k1, k2 = jax.random.split(rng)
    scale = d_model ** -0.5
    return {"w": jax.random.normal(k1, (d_model, num_experts)) * scale,
            "w_noise": jax.random.normal(k2, (d_model, num_experts)) * scale * 0.1}


def noisy_topk_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                       rng: jax.Array | None = None) -> GateOutput:
    """H(x) = x.W + eps * softplus(x.W_noise); top-k over H (train-time noise
    encourages exploration; deterministic when rng is None)."""
    xf = jnp.asarray(x, jnp.float32)
    clean = xf @ jnp.asarray(params["w"], jnp.float32)
    logits = clean
    if rng is not None:
        noise_scale = jax.nn.softplus(
            xf @ jnp.asarray(params["w_noise"], jnp.float32))
        logits = clean + jax.random.normal(rng, clean.shape) * noise_scale
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, expert_ids = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top_logits, axis=-1)
    return GateOutput(expert_ids.astype(jnp.int32), weights, probs, logits)


def expert_choice_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                          capacity: int) -> tuple:
    """Expert-choice routing (Zhou et al. 2022, beyond-paper): each EXPERT
    picks its top-``capacity`` tokens instead of tokens picking experts —
    perfectly load-balanced by construction (no aux loss, no drops beyond
    the capacity itself).

    Returns (token_idx (E, C) int32, weights (E, C) f32, probs (T, E)).
    """
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(params["w"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    # scores transposed: experts choose tokens
    weights, token_idx = jax.lax.top_k(probs.T, capacity)  # (E, C)
    return token_idx.astype(jnp.int32), weights, probs


def expert_choice_moe(params: dict, x: jax.Array, cfg: MoEConfig, *,
                      act: str = "swiglu", capacity_factor: float = 2.0):
    """Full expert-choice MoE layer (gather by expert choice, FFN, scatter-add
    back weighted).  Single-worker reference implementation."""
    from repro.core.fmoe import expert_ffn

    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    E = cfg.num_experts
    C = max(1, int(T * capacity_factor / E))
    token_idx, weights, probs = expert_choice_forward(
        params["router"], xf, cfg, capacity=C)
    bufs = xf[token_idx]  # (E, C, d)
    out = expert_ffn(params["experts"], bufs, act)
    y = jnp.zeros_like(xf)
    y = y.at[token_idx.reshape(-1)].add(
        (out * weights[..., None].astype(out.dtype)).reshape(E * C, -1))
    return y.reshape(shape), probs
