"""Synthetic LM data pipeline.

A deterministic, learnable token stream: a Zipf-distributed unigram base with
an order-2 Markov overlay so the loss has real structure to learn (dense vs
MoE convergence comparisons in the Fig-7 benchmark need a learnable signal,
not uniform noise).  Host-sharded: each data-parallel host slices its batch
rows, matching a multi-host loader's contract.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0,
                 zipf_a: float = 1.2, markov_weight: float = 0.7):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        v = vocab_size
        base = 1.0 / np.arange(1, v + 1) ** zipf_a
        self.base = base / base.sum()
        # sparse order-1 transition structure: each token prefers 4 successors
        g = np.random.default_rng(seed + 1)
        self.succ = g.integers(0, v, size=(v, 4))
        self.markov_weight = markov_weight

    def sample_batch(self, batch: int) -> np.ndarray:
        v = self.vocab_size
        out = np.empty((batch, self.seq_len), np.int32)
        prev = self.rng.choice(v, size=batch, p=self.base)
        out[:, 0] = prev
        for t in range(1, self.seq_len):
            use_markov = self.rng.random(batch) < self.markov_weight
            succ_pick = self.succ[prev, self.rng.integers(0, 4, size=batch)]
            base_pick = self.rng.choice(v, size=batch, p=self.base)
            prev = np.where(use_markov, succ_pick, base_pick).astype(np.int32)
            out[:, t] = prev
        return out

    def reseed_sampler(self, seed: int) -> "SyntheticLM":
        """Fresh sampling stream over the SAME token distribution (same Zipf
        base + Markov map) — for held-out evaluation."""
        self.rng = np.random.default_rng(seed)
        return self

    def batches(self, batch: int, *, host_id: int = 0,
                num_hosts: int = 1) -> Iterator[dict]:
        """Infinite stream of host-local shards of a global batch."""
        assert batch % num_hosts == 0
        local = batch // num_hosts
        while True:
            full = self.sample_batch(batch)
            yield {"tokens": full[host_id * local:(host_id + 1) * local]}


class ByteTokenizer:
    """Trivial byte-level tokenizer (for the quickstart example)."""

    vocab_size = 256

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        return bytes(int(i) % 256 for i in ids).decode("utf-8", errors="replace")
