from repro.data.synthetic import ByteTokenizer, SyntheticLM

__all__ = ["ByteTokenizer", "SyntheticLM"]
