"""Deterministic fault-injection registry for resilience drills (ISSUE 8).

A *fault spec* is a flat dict armed into a process-global registry; code
paths that can fail in production declare named *points* and call
:func:`fire` when they pass them.  Matching specs trigger deterministically
(by hit count or step number), so the same drill replays bit-identically —
the property that lets ``tests/test_resilience.py`` assert exact recovery
behavior instead of "it probably survived".

Spec fields:

``kind``
    ``crash``          — ``os._exit(137)`` at the point (simulates SIGKILL:
                         no atexit, no finally blocks, no flushes).
    ``corrupt_array``  — overwrite bytes of the file named in the point's
                         ``file=`` payload (post-checksum bit-rot; restore
                         must catch it).  Optional ``match`` substring
                         filters on the flat param ``key``.
    ``nonfinite``      — poison a train step: loss/grad_norm -> NaN and
                         every float param leaf NaN-poisoned (what a real
                         overflowed step leaves behind).
    ``drop_spike``     — force ``drop_frac`` in the step metrics to
                         ``value`` (default 1.0) for a step range.
``point``
    The injection site name, e.g. ``ckpt_save_arrays``, ``ckpt_save_file``,
    ``ckpt_save_pre_commit``, ``train_step``.
``at``
    1-based *hit count* trigger: fire on the Nth time this process passes
    the point (one-shot).
``step`` / ``until``
    *Step-number* trigger: fire while ``step`` <= current step < ``until``
    (``until`` defaults to ``step + 1``).  ``nonfinite`` disarms after its
    first firing even with a range, so a guarded retry of the same step
    succeeds — the transient-fault model.

Arming: :func:`arm` programmatically, or the ``REPRO_FAULTS`` env var as a
JSON list so subprocess CLI runs (``repro.launch.train``) can be injected
from tests without code hooks.  Every firing emits a ``{"kind": "fault"}``
obs event through :func:`set_sink` and is appended to :data:`fired`.

Import discipline: stdlib + numpy + jax only — :mod:`repro.checkpoint`
imports this lazily, so no package cycles.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.obs import events as obs_events

ENV_VAR = "REPRO_FAULTS"
CRASH_EXIT_CODE = 137  # what a SIGKILLed process reports (128 + 9)

_ARMED: list = []
_SINK = None
fired: list = []  # record of every firing (tests introspect this)


class _Fault:
    def __init__(self, spec: dict):
        self.spec = dict(spec)
        self.kind = self.spec["kind"]
        self.point = self.spec["point"]
        self.hits = 0
        self.done = False

    def matches(self, step: Optional[int]) -> bool:
        if self.done:
            return False
        if "at" in self.spec:
            return self.hits == int(self.spec["at"])
        if "step" in self.spec:
            if step is None:
                return False
            lo = int(self.spec["step"])
            hi = int(self.spec.get("until", lo + 1))
            return lo <= int(step) < hi
        return True  # unconditional: fires on every pass

    def one_shot(self) -> bool:
        # hit-count triggers always retire; nonfinite retires even on a
        # step range (transient-fault model: the retry must succeed)
        return "at" in self.spec or self.kind in ("crash", "nonfinite")


def arm(spec: dict) -> None:
    """Arm one fault spec (validated minimally: kind + point required)."""
    if "kind" not in spec or "point" not in spec:
        raise ValueError(f"fault spec needs 'kind' and 'point': {spec}")
    _ARMED.append(_Fault(spec))


def arm_specs(specs) -> None:
    for s in specs:
        arm(s)


def arm_from_env(var: str = ENV_VAR) -> int:
    """Arm specs from a JSON list in ``var``; returns how many were armed."""
    raw = os.environ.get(var, "")
    if not raw:
        return 0
    specs = json.loads(raw)
    if isinstance(specs, dict):
        specs = [specs]
    arm_specs(specs)
    return len(specs)


def clear() -> None:
    _ARMED.clear()
    fired.clear()


def set_sink(sink) -> None:
    """Route fault-firing obs events into ``sink`` (None = record only)."""
    global _SINK
    _SINK = sink


def armed() -> list:
    return [f.spec for f in _ARMED if not f.done]


def _record(fault: _Fault, step: Optional[int], info: dict) -> dict:
    rec = {"fault_kind": fault.kind, "point": fault.point,
           "hits": fault.hits, **({"step": step} if step is not None else {}),
           **{k: v for k, v in info.items() if isinstance(v, (str, int, float))}}
    fired.append(rec)
    obs_events.emit(_SINK, obs_events.FAULT, **rec)
    return rec


def fire(point: str, *, step: Optional[int] = None, **info) -> list:
    """Pass an injection point: trigger matching armed faults.

    ``crash`` and ``corrupt_array`` are handled here (the point payload in
    ``info`` carries what they need, e.g. ``file=``); other kinds are
    returned for the caller to apply (see :func:`apply_step`).
    """
    out = []
    for f in _ARMED:
        if f.point != point:
            continue
        if f.kind == "corrupt_array":
            # the match filter gates what counts as a pass of this point,
            # so "at" means "the Nth matching file", not "the Nth file"
            match = f.spec.get("match")
            if match is not None and match not in str(info.get("key", "")):
                continue
        f.hits += 1  # hits counts passes of this point; "at" is 1-based
        if not f.matches(step):
            continue
        if f.one_shot():
            f.done = True
        _record(f, step, info)
        if f.kind == "crash":
            if _SINK is not None:
                try:  # the event above must survive the kill
                    _SINK.close()
                except Exception:
                    pass
            os._exit(CRASH_EXIT_CODE)
        if f.kind == "corrupt_array":
            f.done = True
            corrupt_file(str(info["file"]))
            continue
        out.append(f.spec)
    return out


def corrupt_file(path: str, *, offset: int = -64, nbytes: int = 16) -> None:
    """Flip bytes in ``path`` (payload region by default: ``offset`` < 0 is
    relative to EOF, clamped past the npy header) — deterministic bit-rot.

    Clamping matters: flipping header bytes makes ``np.load`` *error*, a
    different (easier) failure than the silent bad data that checksums
    exist to catch.
    """
    size = os.path.getsize(path)
    pos = max(0, size + offset if offset < 0 else offset)
    with open(path, "r+b") as f:
        if f.read(6) == b"\x93NUMPY":  # keep the corruption in the payload
            major = f.read(2)[0]
            hlen = int.from_bytes(f.read(2 if major == 1 else 4), "little")
            pos = max(pos, f.tell() + hlen)
        pos = min(pos, max(0, size - 1))
        f.seek(pos)
        chunk = f.read(nbytes)
        f.seek(pos)
        f.write(bytes(b ^ 0xFF for b in chunk))


# ---------------------------------------------------------------------------
# Train-step application (host side, after the jitted step returns)
# ---------------------------------------------------------------------------


def apply_step(params, opt_state, metrics, *, step: int):
    """Apply train-step faults at the ``train_step`` point.

    ``nonfinite`` poisons the step exactly the way a real overflow does:
    the reported loss/grad_norm go NaN *and* the updated params are
    NaN-contaminated, so a guard that only patched the metrics (without
    restoring state) would be caught by the next step's loss.
    ``drop_spike`` overrides ``drop_frac`` (and ``dropped``) in the
    metrics, driving the guard's sustained-spike fallback.
    """
    specs = fire("train_step", step=step)
    if not specs:
        return params, opt_state, metrics
    import jax
    import jax.numpy as jnp
    for spec in specs:
        if spec["kind"] == "nonfinite":
            nan = jnp.float32(np.nan)

            def poison(x):
                if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
                    return (x * nan).astype(x.dtype)
                return x

            params = jax.tree.map(poison, params)
            metrics = dict(metrics)
            metrics["loss"] = metrics["loss"] * nan
            if "grad_norm" in metrics:
                metrics["grad_norm"] = metrics["grad_norm"] * nan
        elif spec["kind"] == "drop_spike":
            v = float(spec.get("value", 1.0))
            metrics = dict(metrics)
            metrics["drop_frac"] = jnp.float32(v)
            if "dropped" in metrics:
                metrics["dropped"] = jnp.float32(v * 1e4)
    return params, opt_state, metrics
