"""Step guard: non-finite detection, last-good snapshots, drop-spike
fallback, and the post-replan probation window (ISSUE 8 tentpole).

A single NaN step silently corrupts the weights forever — the loss keeps
"training" on poisoned params long after the incident.  The guard breaks
that failure mode at the train loop:

* :meth:`StepGuard.commit` keeps a *copy* of (params, opt_state) after each
  verified-finite step (every ``snapshot_every``-th to amortize the copy).
  Copies are mandatory — the jitted step donates its input buffers, so a
  bare reference would be invalidated one step later.
* :meth:`StepGuard.check` inspects the step's host-side loss/grad_norm:
  non-finite means the just-written state is discarded and
  :meth:`StepGuard.restore` hands back a fresh copy of the last good
  snapshot for a bounded retry (``max_bad_steps`` consecutive failures
  raise :class:`TrainingAborted` — a persistent NaN is a bug, not a
  transient).
* A sustained ``drop_frac`` above ``drop_threshold`` for ``drop_patience``
  consecutive steps signals the dropless-bound fallback exactly once
  (``GuardVerdict.fallback_dropless``); the train loop re-jits with
  ``ragged_bound=0`` — the provably-dropless shard width.

:class:`ReplanProbation` applies the same skepticism to placement replans:
a freshly migrated plan is on probation for a window of steps, judged
against the pre-replan loss/drop baseline; regression means the migration
is inverted and the plan blacklisted (see launch.train.ReplanHook).

Every skip/restore/abort/spike emits a :mod:`repro.obs.events` record.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs import events as obs_events


class TrainingAborted(RuntimeError):
    """Raised when more than ``max_bad_steps`` consecutive steps go bad."""


class GuardVerdict(NamedTuple):
    ok: bool
    reason: str = ""
    fallback_dropless: bool = False  # only ever True on an ok verdict


def _copy_tree(tree):
    return jax.tree.map(jnp.copy, tree)


class StepGuard:
    def __init__(self, *, max_bad_steps: int = 3, drop_threshold: float = 0.25,
                 drop_patience: int = 4, snapshot_every: int = 1, sink=None):
        self.max_bad_steps = int(max_bad_steps)
        self.drop_threshold = float(drop_threshold)
        self.drop_patience = int(drop_patience)
        self.snapshot_every = max(1, int(snapshot_every))
        self.sink = sink
        self._snap = None  # (params, opt_state) copies
        self._snap_step = None
        self.bad_streak = 0
        self.bad_total = 0
        self._drop_streak = 0
        self._fallback_signalled = False

    # -- snapshots ----------------------------------------------------------

    def commit(self, step: int, params, opt_state, *,
               force: bool = False) -> None:
        """Record a verified-good state (copied; survives buffer donation).

        Resets the consecutive-bad counter; snapshots every
        ``snapshot_every``-th committed step (the first always).  ``force``
        snapshots regardless of cadence — the train loop forces one after
        every placement migration so a later restore never reinstates
        params in a stale physical layout under a re-jitted step.
        """
        self.bad_streak = 0
        due = (force or self._snap is None or self.snapshot_every == 1
               or step - self._snap_step >= self.snapshot_every)
        if due:
            self._snap = _copy_tree((params, opt_state))
            self._snap_step = step

    def restore(self):
        """Fresh copies of the last good (params, opt_state).

        Copies again so the caller can feed them into a donating step
        function while the snapshot stays intact for repeated retries.
        """
        if self._snap is None:
            raise TrainingAborted("no good state to restore from")
        obs_events.emit(self.sink, obs_events.GUARD_RESTORE,
                        step=self._snap_step)
        return _copy_tree(self._snap)

    @property
    def snapshot_step(self) -> Optional[int]:
        return self._snap_step

    @property
    def snapshot(self):
        """The raw last-good (params, opt_state) — no copy, no event (for
        crash-consistent final saves on abort; do not train on these)."""
        return self._snap

    # -- per-step verdict ---------------------------------------------------

    def check(self, step: int, *, loss: float, grad_norm: Optional[float] = None,
              drop: float = 0.0) -> GuardVerdict:
        bad = not math.isfinite(loss)
        if grad_norm is not None and not math.isfinite(grad_norm):
            bad = True
        if bad:
            self.bad_streak += 1
            self.bad_total += 1
            obs_events.emit(self.sink, obs_events.GUARD_SKIP, step=step,
                            loss=float(loss),
                            grad_norm=(None if grad_norm is None
                                       else float(grad_norm)),
                            bad_streak=self.bad_streak)
            if self.bad_streak > self.max_bad_steps:
                obs_events.emit(self.sink, obs_events.GUARD_ABORT, step=step,
                                bad_streak=self.bad_streak)
                raise TrainingAborted(
                    f"step {step}: {self.bad_streak} consecutive non-finite "
                    f"steps (> max_bad_steps={self.max_bad_steps})")
            return GuardVerdict(False, "nonfinite")
        # drop spikes only tick on finite steps (a NaN step's drop counter
        # is as poisoned as its loss)
        if drop > self.drop_threshold:
            self._drop_streak += 1
        else:
            self._drop_streak = 0
        fb = False
        if (self._drop_streak >= self.drop_patience
                and not self._fallback_signalled):
            fb = True
            self._fallback_signalled = True  # one fallback per run
            self._drop_streak = 0
            obs_events.emit(self.sink, obs_events.DROP_SPIKE, step=step,
                            drop_frac=float(drop),
                            patience=self.drop_patience,
                            threshold=self.drop_threshold)
        return GuardVerdict(True, fallback_dropless=fb)


# ---------------------------------------------------------------------------
# Replan probation (the rollback brain; ReplanHook executes the migration)
# ---------------------------------------------------------------------------


class ProbationDecision(NamedTuple):
    rollback: bool
    reason: str = ""
    old_plan: object = None  # the plan to roll back to (rollback=True only)
    new_plan: object = None  # the regressing plan (for blacklisting)


class ReplanProbation:
    """Judge a freshly applied placement plan against pre-replan baselines.

    ``start`` opens a ``window``-step probation carrying the old plan and
    the baseline loss/drop EMAs; ``observe`` feeds post-replan per-step
    metrics.  Once ``min_samples`` have accrued, a post-replan mean loss
    above ``baseline * loss_tol`` or mean drop above
    ``baseline + drop_tol`` returns a rollback decision immediately;
    surviving the window commits the plan.  Missing metrics (None) simply
    don't participate — a drop-only caller still gets drop protection.
    """

    def __init__(self, *, window: int = 16, loss_tol: float = 1.05,
                 drop_tol: float = 0.05, min_samples: int = 3, sink=None):
        self.window = int(window)
        self.loss_tol = float(loss_tol)
        self.drop_tol = float(drop_tol)
        self.min_samples = int(min_samples)
        self.sink = sink
        self._active = None

    @property
    def active(self) -> bool:
        return self._active is not None

    @property
    def old_plan(self):
        return self._active["old"] if self._active else None

    @property
    def new_plan(self):
        return self._active["new"] if self._active else None

    def start(self, step: int, old_plan, new_plan, *,
              baseline_loss: Optional[float] = None,
              baseline_drop: Optional[float] = None) -> None:
        self._active = {"start": step, "old": old_plan, "new": new_plan,
                        "baseline_loss": baseline_loss,
                        "baseline_drop": baseline_drop,
                        "losses": [], "drops": []}

    def _finish(self, step: int, kind: str, **fields) -> None:
        obs_events.emit(self.sink, kind, step=step,
                        start=self._active["start"], **fields)
        self._active = None

    def observe(self, step: int, *, loss: Optional[float] = None,
                drop: Optional[float] = None) -> ProbationDecision:
        """Feed one post-replan step; decides rollback/commit/keep-watching."""
        a = self._active
        if a is None:
            return ProbationDecision(False)
        if loss is not None and math.isfinite(loss):
            a["losses"].append(float(loss))
        if drop is not None and math.isfinite(drop):
            a["drops"].append(float(drop))
        n = max(len(a["losses"]), len(a["drops"]))
        if n >= self.min_samples:
            bl, bd = a["baseline_loss"], a["baseline_drop"]
            old, new = a["old"], a["new"]
            if (bl is not None and a["losses"]
                    and sum(a["losses"]) / len(a["losses"]) > bl * self.loss_tol):
                mean = sum(a["losses"]) / len(a["losses"])
                self._finish(step, obs_events.REPLAN_ROLLBACK, metric="loss",
                             mean=mean, baseline=bl)
                return ProbationDecision(True,
                                         f"loss {mean:.4f} > {bl:.4f}"
                                         f" * {self.loss_tol}", old, new)
            if (bd is not None and a["drops"]
                    and sum(a["drops"]) / len(a["drops"]) > bd + self.drop_tol):
                mean = sum(a["drops"]) / len(a["drops"])
                self._finish(step, obs_events.REPLAN_ROLLBACK, metric="drop",
                             mean=mean, baseline=bd)
                return ProbationDecision(True,
                                         f"drop {mean:.4f} > {bd:.4f}"
                                         f" + {self.drop_tol}", old, new)
        if step - a["start"] >= self.window:
            self._finish(step, obs_events.REPLAN_COMMIT)
        return ProbationDecision(False)
