"""Auto-resume logic: periodic atomic saves, retention GC, and
newest-complete-wins restore (ISSUE 8).

:class:`CheckpointManager` owns one checkpoint root for a training run:

* :meth:`maybe_save` commits ``step_<N>`` atomically every ``save_every``
  steps (checkpoints hold the state *after* completing step N, always in
  logical expert order via the ``placement`` kwarg) and then GCs down to
  the ``keep`` newest.
* :meth:`restore_latest` walks complete checkpoints newest-first and
  returns the first that passes full verification — a corrupt newest
  checkpoint (bit-rot, torn legacy write) is *skipped with an obs event*,
  not fatal, so a run can always come back from the last good state.

The manager emits ``ckpt_save`` / ``ckpt_gc`` / ``ckpt_corrupt`` /
``resume`` events into its sink, extending the incident timeline that the
guard and fault registry write (:mod:`repro.obs.events`).
"""
from __future__ import annotations

import os
from typing import Any, Optional

from repro.checkpoint import ckpt
from repro.obs import events as obs_events


class CheckpointManager:
    def __init__(self, root: str, *, save_every: int = 0, keep: int = 3,
                 sink=None):
        self.root = root
        self.save_every = int(save_every)
        self.keep = max(1, int(keep))
        self.sink = sink
        self._last_saved: Optional[int] = None
        os.makedirs(root, exist_ok=True)

    def step_dir(self, step: int) -> str:
        return ckpt.step_path(self.root, step)

    def maybe_save(self, step: int, tree: Any, *, placement=None,
                   force: bool = False) -> Optional[str]:
        """Save iff step N completes a ``save_every`` interval (or ``force``).

        The cadence counts *completed* steps: with ``save_every=2`` the
        saves land after steps 1, 3, 5, ... — so a run of 2k steps always
        ends on a checkpoint boundary.  Never double-saves one step.
        """
        if self._last_saved == step:
            return None
        due = self.save_every > 0 and (step + 1) % self.save_every == 0
        if not (due or force):
            return None
        return self.save(step, tree, placement=placement)

    def save(self, step: int, tree: Any, *, placement=None) -> str:
        path = self.step_dir(step)
        ckpt.save(path, tree, step=step, placement=placement)
        self._last_saved = step
        obs_events.emit(self.sink, obs_events.CKPT_SAVE, step=step, path=path)
        removed = ckpt.gc_checkpoints(self.root, keep=self.keep)
        if removed:
            obs_events.emit(self.sink, obs_events.CKPT_GC, step=step,
                            removed=len(removed))
        return path

    def restore_latest(self, like: Any, *, placement=None):
        """``(tree, step)`` from the newest checkpoint that verifies, or
        None when the root holds no restorable checkpoint.  Verification
        failures fall back to the next-older complete checkpoint."""
        for step, path in reversed(ckpt.complete_steps(self.root)):
            try:
                tree = ckpt.restore(path, like, placement=placement)
            except (ckpt.CheckpointError, OSError) as e:
                obs_events.emit(self.sink, obs_events.CKPT_CORRUPT, step=step,
                                path=path, error=str(e))
                continue
            obs_events.emit(self.sink, obs_events.RESUME, step=step,
                            path=path)
            return tree, step
        return None
