"""Resilience layer (ISSUE 8): fault-tolerant training for a system that
replans placement and re-jits mid-run.

* :mod:`faults` — deterministic fault-injection registry (crash-at-point,
  corrupt-array, inject-nonfinite, drop-spike), armable from the
  ``REPRO_FAULTS`` env var for subprocess drills.
* :mod:`guard` — the per-step guard: non-finite loss/grad detection with
  bounded retry from a last-good snapshot, sustained-drop-spike fallback
  to the dropless bound, and the post-replan probation window.
* :mod:`recovery` — :class:`CheckpointManager`: periodic atomic verified
  saves with retention GC and newest-complete-wins auto-resume.

Import order matters for the lazy cycle with :mod:`repro.checkpoint`
(ckpt fires fault points): ``faults`` first, then ``guard``, then
``recovery`` (which imports checkpoint).
"""
from repro.resilience import faults  # noqa: F401  (must import first)
from repro.resilience.guard import (GuardVerdict, ProbationDecision,  # noqa: F401
                                    ReplanProbation, StepGuard,
                                    TrainingAborted)
from repro.resilience.recovery import CheckpointManager  # noqa: F401

__all__ = ["CheckpointManager", "GuardVerdict", "ProbationDecision",
           "ReplanProbation", "StepGuard", "TrainingAborted", "faults"]
