"""Pluggable metrics sinks: one ``emit(record)`` interface shared by the
train loop (--metrics_out), the serve loop, LoadMonitor/ReplanHook, and the
benchmark driver — replacing the bespoke CSV/JSON writers each had grown.

Records are flat dicts; array/device values are coerced to plain Python
scalars/lists at the sink boundary (the caller decides *when* to force the
device→host transfer — sinks never touch jax).
"""
from __future__ import annotations

import csv
import json
import os
from collections import deque
from typing import Optional

import numpy as np


def _coerce(v):
    """Device arrays / numpy scalars -> JSON-serializable Python values."""
    if hasattr(v, "__array__") or isinstance(v, np.generic):
        a = np.asarray(v)
        if a.dtype.kind not in "ifub":  # bf16 etc: go through float32
            a = a.astype(np.float32)
        return a.item() if a.ndim == 0 else a.tolist()
    return v


class MetricsSink:
    """Base interface.  ``emit`` one flat dict per record."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class JsonlSink(MetricsSink):
    """One JSON object per line, flushed per record (crash-safe tails)."""

    def __init__(self, path: str, *, append: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a" if append else "w")

    def emit(self, record: dict) -> None:
        json.dump({k: _coerce(v) for k, v in record.items()}, self._f)
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class CsvSink(MetricsSink):
    """CSV writer; column set locks at the first record (later extra keys
    are dropped, missing ones left empty — CSV has one header)."""

    def __init__(self, path: str, *, fieldnames: Optional[list] = None):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w", newline="")
        self._fieldnames = list(fieldnames) if fieldnames else None
        self._writer = None

    def emit(self, record: dict) -> None:
        rec = {k: _coerce(v) for k, v in record.items()}
        if self._writer is None:
            if self._fieldnames is None:
                self._fieldnames = list(rec)
            self._writer = csv.DictWriter(self._f, self._fieldnames,
                                          extrasaction="ignore")
            self._writer.writeheader()
        self._writer.writerow(rec)
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class MemorySink(MetricsSink):
    """In-memory ring for tests and the LoadMonitor's bounded history."""

    def __init__(self, capacity: Optional[int] = None):
        self._records: deque = deque(maxlen=capacity)

    def emit(self, record: dict) -> None:
        self._records.append({k: _coerce(v) for k, v in record.items()})

    @property
    def records(self) -> list:
        return list(self._records)


class MultiSink(MetricsSink):
    def __init__(self, *sinks: MetricsSink):
        self.sinks = [s for s in sinks if s is not None]

    def emit(self, record: dict) -> None:
        for s in self.sinks:
            s.emit(record)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def jsonl_records(path: str) -> list:
    """Read back a JsonlSink file (tests / tooling)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
