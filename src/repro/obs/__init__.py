"""Unified telemetry (ISSUE 6): device-side wire/drop/shadow counters
(:mod:`counters`), the host-side span tracer with Chrome-trace export
(:mod:`trace`), pluggable metrics sinks (:mod:`sink`), the
modeled-vs-measured StepStats record (:mod:`stats`), and the resilience
layer's incident-event vocabulary (:mod:`events`).

Import discipline: :mod:`counters` depends only on jax, :mod:`trace` and
:mod:`sink` only on the stdlib (+numpy), so ``repro.core`` may import them
without cycles; :mod:`stats` pulls ``repro.launch.roofline`` lazily.
"""
from repro.obs import events  # noqa: F401
from repro.obs import trace  # noqa: F401
from repro.obs.counters import ObsCounters  # noqa: F401
from repro.obs.sink import (CsvSink, JsonlSink, MemorySink,  # noqa: F401
                            MetricsSink, MultiSink, jsonl_records)
from repro.obs.stats import StepStats, modeled_collective_bytes  # noqa: F401
