"""Canonical incident-event vocabulary for the resilience layer (ISSUE 8).

Every fault injection, guarded step skip, state restore, dropless fallback,
checkpoint save/skip, resume, and placement rollback emits one flat record
through the same :mod:`repro.obs.sink` pipeline as the per-step telemetry,
so ``--metrics_out`` carries the *whole incident timeline* — what fired,
what the guard did about it, and where training picked back up — in one
queryable stream.  The kinds live here (not scattered as string literals)
so tests and tooling can filter on one vocabulary.
"""
from __future__ import annotations

# fault registry (repro.resilience.faults)
FAULT = "fault"  # an armed fault fired at its point

# step guard (repro.resilience.guard)
GUARD_SKIP = "guard_skip"  # non-finite step detected; state discarded
GUARD_RESTORE = "guard_restore"  # last-good snapshot reinstated for retry
GUARD_ABORT = "guard_abort"  # max_bad_steps exceeded; training stopped
DROP_SPIKE = "drop_spike"  # sustained drop_frac above threshold
DROP_FALLBACK = "drop_fallback"  # train loop forced the dropless bound

# checkpointing (repro.resilience.recovery / repro.checkpoint)
CKPT_SAVE = "ckpt_save"  # atomic checkpoint committed
CKPT_GC = "ckpt_gc"  # retention GC removed old checkpoints
CKPT_CORRUPT = "ckpt_corrupt"  # a checkpoint failed verification on restore
RESUME = "resume"  # training resumed from a complete checkpoint

# placement replan probation (launch.train.ReplanHook)
REPLAN_ROLLBACK = "replan_rollback"  # post-replan regression: plan reverted
REPLAN_COMMIT = "replan_commit"  # probation passed; new plan kept

# routing (launch.train --freeze_router_at)
ROUTER_FROZEN = "router_frozen"  # gate distillation ended; frozen router live

# telemetry self-reporting (launch.train modeled bytes)
MODELED_ERROR = "modeled_bytes_error"  # HLO byte modeling unavailable


def emit(sink, kind: str, **fields) -> dict | None:
    """Emit ``{"kind": kind, **fields}`` into ``sink`` (None sink = no-op).

    Returns the record (or None) so call sites can also print/log it.
    """
    if sink is None:
        return None
    rec = {"kind": kind, **fields}
    sink.emit(rec)
    return rec


def of_kind(records: list, *kinds: str) -> list:
    """Filter a record stream (e.g. ``jsonl_records`` output) by kind."""
    want = set(kinds)
    return [r for r in records if r.get("kind") in want]
