"""Device-side telemetry counters riding the MoE metrics pytree.

One :class:`ObsCounters` per MoE layer, accumulated across layers by the
model's layer scan exactly like the rest of :class:`repro.core.balance.
MoEMetrics` — the counters are ordinary array leaves of the metrics output,
so they reach the host on the same transfer as the loss and add **zero**
extra device→host syncs (tests/test_obs.py locks the stronger property:
zero extra collectives in the optimized HLO, byte-for-byte).

The fields are derived only from (a) trace-time constants — buffer shapes,
wire dtypes, the ppermute decomposition factor — and (b) values the
distributed paths already reduce for the load monitor (the Fig-2 counts
exchange / psum'd group sizes and the pmean'd drop fraction).  That is what
keeps them free: no counter introduces a collective of its own.

Semantics (per train/decode step, summed over MoE layers):

  wire_elems / wire_bytes — elements/bytes of the expert exchange that
    actually cross the wire **per device**: dispatch + return payloads (at
    ``DistConfig.wire_dtype`` width) plus the counts exchange, scaled by
    (mp-1)/mp when the §5.2 schedule decomposes the all-to-all into
    ppermutes (a rank's own slice never leaves the chip).  Comparable 1:1
    with ``roofline.collective_bytes`` parsed from the optimized HLO.
  dropped — (token, slot) assignments dropped **globally** (capacity
    overflow or ragged-bound overflow).
  shadow_hits — assignments served by shadowed (replicated) hot experts
    globally; these rows never cross the wire.
  imbalance — max/mean of per-expert-rank received load (1.0 = perfectly
    balanced).  Summed over layers like the rest; divide by num_layers for
    the per-layer average (models/lm.loss_fn does).
  wire_bytes_intra / wire_bytes_inter — ``wire_bytes`` split by mesh level:
    ``inter`` is what crosses the node boundary (the slow links the
    hierarchical exchange slims), ``intra`` what stays on the node-local
    axis.  Flat (single-level) exchanges count everything as ``inter`` —
    every rank pair talks directly, so every byte potentially crosses a
    node boundary; the two-level ragged path splits its legs.  Always
    ``wire_bytes == wire_bytes_intra + wire_bytes_inter``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ObsCounters(NamedTuple):
    """Per-layer device-side counters (all f32 scalars, '+'-accumulable)."""

    wire_elems: jax.Array  # exchange elements crossing the wire, per device
    wire_bytes: jax.Array  # same in bytes (payload at wire_dtype + counts)
    dropped: jax.Array  # global dropped (token, slot) assignments
    shadow_hits: jax.Array  # global assignments served by shadowed experts
    imbalance: jax.Array  # max/mean per-rank received load (1.0 = balanced)
    wire_bytes_intra: jax.Array  # node-local share of wire_bytes
    wire_bytes_inter: jax.Array  # cross-node share (== wire_bytes when flat)

    @staticmethod
    def zero() -> "ObsCounters":
        z = jnp.zeros(())
        return ObsCounters(z, z, z, z, z, z, z)

    def __add__(self, other: "ObsCounters") -> "ObsCounters":
        return ObsCounters(*(a + b for a, b in zip(self, other)))

    def as_dict(self) -> dict:
        return dict(zip(self._fields, self))


def exchange_counters(*, frac: float, fwd_rows: int, d_in: int, in_dtype,
                      ret_rows: int, d_out: int, out_dtype, counts_elems: int,
                      wire_dtype=None, dropped, shadow_hits,
                      imbalance) -> ObsCounters:
    """Counters for one a2a-style exchange (capacity or ragged).

    ``frac`` is the wire fraction of the nominal buffer (see
    ``repro.core.pipeline.wire_fraction``); payload widths honor
    ``wire_dtype`` when the exchange casts across the wire, the counts leg
    is always int32.  ``dropped`` / ``shadow_hits`` / ``imbalance`` are the
    already-reduced values the caller derived from existing collectives.
    """
    bi = jnp.dtype(wire_dtype if wire_dtype is not None else in_dtype).itemsize
    bo = jnp.dtype(wire_dtype if wire_dtype is not None else out_dtype).itemsize
    elems = frac * (fwd_rows * d_in + ret_rows * d_out + counts_elems)
    byts = frac * (fwd_rows * d_in * bi + ret_rows * d_out * bo
                   + counts_elems * 4)
    # a flat exchange has every rank pair talking directly: all bytes are
    # accounted as crossing the node boundary (wire_bytes_inter)
    return ObsCounters(jnp.float32(elems), jnp.float32(byts),
                       jnp.asarray(dropped, jnp.float32),
                       jnp.asarray(shadow_hits, jnp.float32),
                       jnp.asarray(imbalance, jnp.float32),
                       jnp.zeros(()), jnp.float32(byts))


def hier_exchange_counters(*, intra_frac: float, inter_frac: float,
                           intra_rows: int, inter_rows: int, d_in: int,
                           in_dtype, d_out: int, out_dtype, counts_elems: int,
                           wire_dtype=None, dropped, shadow_hits,
                           imbalance) -> ObsCounters:
    """Counters for the two-level (hierarchical) ragged exchange.

    Each level runs a forward + return payload leg plus a counts leg:
    the intra-node hops move ``intra_rows`` rows each way over the fast
    node-local axis, the inter-node hops ``inter_rows`` rows over the slow
    axis (the slimmed buffers).  The counts buffer keeps full per-source-rank
    granularity on both levels (``counts_elems`` int32 each).  ``intra_frac``
    / ``inter_frac`` are each level's own ppermute wire fractions.
    """
    bi = jnp.dtype(wire_dtype if wire_dtype is not None else in_dtype).itemsize
    bo = jnp.dtype(wire_dtype if wire_dtype is not None else out_dtype).itemsize
    elems = (intra_frac * (intra_rows * (d_in + d_out) + counts_elems)
             + inter_frac * (inter_rows * (d_in + d_out) + counts_elems))
    b_intra = intra_frac * (intra_rows * (d_in * bi + d_out * bo)
                            + counts_elems * 4)
    b_inter = inter_frac * (inter_rows * (d_in * bi + d_out * bo)
                            + counts_elems * 4)
    return ObsCounters(jnp.float32(elems), jnp.float32(b_intra + b_inter),
                       jnp.asarray(dropped, jnp.float32),
                       jnp.asarray(shadow_hits, jnp.float32),
                       jnp.asarray(imbalance, jnp.float32),
                       jnp.float32(b_intra), jnp.float32(b_inter))


def reduction_counters(*, payload_elems: int, payload_dtype, dropped,
                       shadow_hits, imbalance) -> ObsCounters:
    """Counters for the psum (decode) mode: one all-reduce of the combined
    output is the only wire traffic (there is no counts leg)."""
    b = jnp.dtype(payload_dtype).itemsize
    return ObsCounters(jnp.float32(payload_elems),
                       jnp.float32(payload_elems * b),
                       jnp.asarray(dropped, jnp.float32),
                       jnp.asarray(shadow_hits, jnp.float32),
                       jnp.asarray(imbalance, jnp.float32),
                       jnp.zeros(()), jnp.float32(payload_elems * b))


def local_counters(*, dropped) -> ObsCounters:
    """Single-worker path: nothing crosses any wire."""
    z = jnp.zeros(())
    return ObsCounters(z, z, jnp.asarray(dropped, jnp.float32), z,
                       jnp.float32(1.0), z, z)
