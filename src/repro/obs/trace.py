"""Host-side span tracer: low-overhead wall-time spans with Chrome-trace
JSON export.

Disabled by default — ``span()`` is then a no-op context manager costing one
attribute read, so instrumented code paths (train step, replan/migrate,
checkpoint, serve decode) can leave their spans in unconditionally.  Enable
with :func:`configure`; export with :func:`export` (view in
``chrome://tracing`` / https://ui.perfetto.dev).

Events are complete-span ("ph": "X") Chrome trace events in microseconds
relative to tracer start, ring-buffered so long runs can't leak host memory.
Nesting is implicit (Chrome derives it from ts/dur on one tid), but the
tracer also records the span ``depth`` for programmatic consumers.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional


class Tracer:
    def __init__(self, *, enabled: bool = True, max_events: int = 100_000,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.max_events = max_events
        self._clock = clock
        self._t0 = clock()
        self._events: deque = deque(maxlen=max_events)
        self._local = threading.local()

    @contextmanager
    def span(self, name: str, **args):
        """Time a region.  Yields the (mutable) args dict when enabled so the
        body can attach results (``s["tokens"] = n``), or None when disabled.
        """
        if not self.enabled:
            yield None
            return
        depth = getattr(self._local, "depth", 0)
        self._local.depth = depth + 1
        t0 = self._clock()
        try:
            yield args
        finally:
            t1 = self._clock()
            self._local.depth = depth
            ev = {"name": name, "ph": "X",
                  "ts": (t0 - self._t0) * 1e6,
                  "dur": (t1 - t0) * 1e6,
                  "pid": 0, "tid": threading.get_ident(),
                  "args": {"depth": depth, **args}}
            self._events.append(ev)

    @property
    def events(self) -> list:
        return list(self._events)

    def chrome_trace(self) -> dict:
        return {"traceEvents": list(self._events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def reset(self) -> None:
        self._events.clear()
        self._t0 = self._clock()


# Module-level singleton: the instrumented code paths (train/serve/ckpt/
# benchmarks) all talk to this, so one --trace flag lights them all up.
_TRACER = Tracer(enabled=False)


def configure(*, enabled: bool = True,
              max_events: int = 100_000) -> Tracer:
    """(Re)configure the global tracer; returns it."""
    global _TRACER
    _TRACER = Tracer(enabled=enabled, max_events=max_events)
    return _TRACER


def get() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **args):
    return _TRACER.span(name, **args)


def export(path: str) -> str:
    return _TRACER.export(path)


def reset() -> None:
    _TRACER.reset()


def load_trace(path: str) -> dict:
    """Read back an exported Chrome trace (tests / tooling)."""
    with open(path) as f:
        return json.load(f)
