"""StepStats: one record merging measured wall time + device-side counters
with the HLO-derived *modeled* collective bytes (repro.launch.roofline) —
the modeled-vs-measured comparison the ROADMAP's wire-byte evidence calls
for, in a shape any MetricsSink can emit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


def modeled_collective_bytes(compiled_or_text) -> dict:
    """Per-op-type collective bytes from a compiled step (or its HLO text)."""
    from repro.launch.roofline import collective_bytes

    text = (compiled_or_text if isinstance(compiled_or_text, str)
            else compiled_or_text.as_text())
    return collective_bytes(text)


@dataclass
class StepStats:
    """One step's telemetry: wall time, device counters, modeled bytes."""

    name: str
    step: int
    wall_s: float
    counters: dict = field(default_factory=dict)  # measured (device-side)
    modeled: dict = field(default_factory=dict)  # HLO collective bytes by op

    @property
    def measured_wire_bytes(self) -> Optional[float]:
        v = self.counters.get("wire_bytes")
        return float(v) if v is not None else None

    @property
    def modeled_wire_bytes(self) -> float:
        """The exchange ops the wire counters cover: all-to-all when the
        schedule is serial, collective-permute when ppermute-decomposed."""
        return float(self.modeled.get("all-to-all", 0)
                     + self.modeled.get("collective-permute", 0))

    @property
    def wire_ratio(self) -> Optional[float]:
        m = self.measured_wire_bytes
        if m is None or not self.modeled:
            return None
        return m / max(self.modeled_wire_bytes, 1e-9)

    def record(self) -> dict:
        """Flat dict for a MetricsSink."""
        rec = {"kind": self.name, "step": self.step, "wall_s": self.wall_s}
        rec.update({k: v for k, v in self.counters.items()})
        for op, b in self.modeled.items():
            rec[f"modeled_{op.replace('-', '_')}_bytes"] = b
        r = self.wire_ratio
        if r is not None:
            rec["wire_measured_over_modeled"] = r
        return rec
