"""Sharding-aware checkpointing (paper §6 lists MoE save/load as future work).

Layout: one ``.npz``-style directory per step with a JSON manifest mapping
flat param paths -> file names + dtypes + shapes.  Expert-parallel params are
gathered to host before save (addressable shards concatenated), so a
checkpoint written on any mesh restores on any other mesh — the property
FastMoE's tag system makes hard and sharding-by-spec makes trivial.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

from repro.obs import trace as obs_trace


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree: Any, *, step: int | None = None,
         placement=None) -> None:
    """``placement`` (ExpertPlacement or PerLayerPlacement): the live tree's
    physical expert layout.  It is undone before writing (per-layer plans
    un-permute each layer's slice), so checkpoints are always in logical
    expert order — layout-free, restorable under any future placement."""
    with obs_trace.span("ckpt_save", path=path, step=step):
        if placement is not None:
            from repro.placement.migrate import to_logical
            tree = to_logical(tree, placement)
        os.makedirs(path, exist_ok=True)
        flat = _flatten(tree)
        manifest = {"step": step, "params": {}}
        for i, (key, val) in enumerate(flat.items()):
            arr = np.asarray(jax.device_get(val))
            dtype = str(arr.dtype)
            if dtype == "bfloat16":  # np.save can't serialize ml_dtypes
                arr = arr.astype(np.float32)
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(path, fname), arr)
            manifest["params"][key] = {"file": fname, "dtype": dtype,
                                       "shape": list(arr.shape)}
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)


def restore(path: str, like: Any, *, placement=None) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``placement`` re-applies a physical expert layout to the logical-order
    checkpoint (the inverse of :func:`save`'s ``placement``) — restoring
    under a *different* plan than the one saved under is fine, which is the
    point: checkpoints don't know layouts."""
    with obs_trace.span("ckpt_restore", path=path):
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(like)
        missing = set(flat_like) - set(manifest["params"])
        extra = set(manifest["params"]) - set(flat_like)
        if missing or extra:
            raise ValueError(
                f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                f"extra={sorted(extra)[:5]}")
        loaded = {}
        for key, meta in manifest["params"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            want = flat_like[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"{key}: shape {arr.shape} != {tuple(want.shape)}")
            loaded[key] = arr.astype(want.dtype)
        tree = _unflatten_like(like, loaded, "")
        if placement is not None:
            from repro.placement.migrate import from_logical
            tree = from_logical(tree, placement)
        return tree


def _unflatten_like(like: Any, flat: dict, prefix: str) -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(like[k], flat, f"{prefix}{k}/") for k in like}
    if hasattr(like, "_fields"):
        return type(like)(*(_unflatten_like(getattr(like, k), flat, f"{prefix}{k}/")
                            for k in like._fields))
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_like(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    return flat[prefix[:-1]]


def latest_step(root: str) -> str | None:
    if not os.path.isdir(root):
        return None
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    return os.path.join(root, steps[-1]) if steps else None
