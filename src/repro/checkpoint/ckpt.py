"""Sharding-aware checkpointing (paper §6 lists MoE save/load as future work).

Layout: one ``.npz``-style directory per step with a JSON manifest mapping
flat param paths -> file names + dtypes + shapes + sha256 checksums.
Expert-parallel params are gathered to host before save (addressable shards
concatenated), so a checkpoint written on any mesh restores on any other
mesh — the property FastMoE's tag system makes hard and sharding-by-spec
makes trivial.

Durability contract (ISSUE 8):

* **Atomic commit** — arrays and manifest are written to a hidden temp
  directory (``.tmp-<name>.<pid>``), fsynced, and published with a single
  ``os.replace``.  A crash (even SIGKILL) mid-save leaves only the temp
  dir, which :func:`latest_step` / :func:`complete_steps` never consider.
* **Verified restore** — the manifest carries a ``"complete": true``
  marker (written last, inside the atomic unit) and a per-array sha256;
  :func:`restore` refuses incomplete manifests and checksum mismatches
  with :class:`CheckpointError`, so bit-rot or a torn write can never be
  silently loaded.  Caller-contract violations (structure/shape/dtype
  mismatch vs ``like``) stay ``ValueError``.
* **Retention GC** — :func:`gc_checkpoints` keeps the newest N complete
  checkpoints and sweeps stale temp dirs.

Fault-injection points (``ckpt_save_file``, ``ckpt_save_arrays``,
``ckpt_save_pre_commit``) let :mod:`repro.resilience.faults` drill
crash-mid-save and corrupt-array scenarios deterministically.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.obs import trace as obs_trace

MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointError(RuntimeError):
    """A checkpoint on disk is missing, incomplete, or fails verification."""


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(path: str, tree: Any, *, step: int | None = None,
         placement=None) -> None:
    """``placement`` (ExpertPlacement or PerLayerPlacement): the live tree's
    physical expert layout.  It is undone before writing (per-layer plans
    un-permute each layer's slice), so checkpoints are always in logical
    expert order — layout-free, restorable under any future placement.

    The write is atomic: everything lands in a sibling temp dir that is
    fsynced and then ``os.replace``d over ``path`` — readers see either
    the old checkpoint or the complete new one, never a torn mix.
    """
    from repro.resilience import faults  # lazy: avoids a package cycle
    with obs_trace.span("ckpt_save", path=path, step=step):
        if placement is not None:
            from repro.placement.migrate import to_logical
            tree = to_logical(tree, placement)
        path = os.path.abspath(path)
        parent, base = os.path.split(path)
        os.makedirs(parent, exist_ok=True)
        tmp = os.path.join(parent, f".tmp-{base}.{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        manifest = {"format": 2, "step": step, "complete": True, "params": {}}
        for i, (key, val) in enumerate(flat.items()):
            arr = np.asarray(jax.device_get(val))
            dtype = str(arr.dtype)
            if dtype == "bfloat16":  # np.save can't serialize ml_dtypes
                arr = arr.astype(np.float32)
            fname = f"arr_{i:05d}.npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, arr)
            _fsync_file(fpath)
            digest = _sha256(fpath)
            # post-checksum injection point: models bit-rot after the write
            # (restore must catch the checksum mismatch)
            faults.fire("ckpt_save_file", file=fpath, key=key)
            manifest["params"][key] = {"file": fname, "dtype": dtype,
                                       "shape": list(arr.shape),
                                       "sha256": digest}
        # crash here == SIGKILL mid-save: arrays on disk, no manifest — the
        # temp dir is invisible to latest_step/complete_steps
        faults.fire("ckpt_save_arrays", step=step, path=path)
        mpath = os.path.join(tmp, MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        _fsync_file(tmp)
        # crash here == SIGKILL after a fully written temp dir but before
        # the atomic publish: still invisible, still recoverable
        faults.fire("ckpt_save_pre_commit", step=step, path=path)
        if os.path.isdir(path):  # re-save of the same step: replace wholesale
            shutil.rmtree(path)
        os.replace(tmp, path)
        _fsync_file(parent)


def load_manifest(path: str) -> dict:
    """The checkpoint's manifest; :class:`CheckpointError` when missing or
    unreadable (a torn legacy write, not a caller bug)."""
    mpath = os.path.join(path, MANIFEST)
    try:
        with open(mpath) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"{path}: unreadable manifest ({e})") from e


def is_complete(path: str) -> bool:
    """True iff ``path`` holds a committed checkpoint (manifest present and
    carrying the ``"complete"`` marker)."""
    try:
        return bool(load_manifest(path).get("complete"))
    except CheckpointError:
        return False


def restore(path: str, like: Any, *, placement=None, verify: bool = True) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    Refuses incomplete checkpoints and (with ``verify``, the default)
    arrays whose sha256 no longer matches the manifest — both
    :class:`CheckpointError`.  Dtypes must match the ``like`` tree exactly;
    the one allowed coercion is the documented bf16<->f32 *storage*
    round-trip (bf16 leaves are stored as f32 files and cast back), which
    stays within the manifest's declared dtype.

    ``placement`` re-applies a physical expert layout to the logical-order
    checkpoint (the inverse of :func:`save`'s ``placement``) — restoring
    under a *different* plan than the one saved under is fine, which is the
    point: checkpoints don't know layouts.
    """
    with obs_trace.span("ckpt_restore", path=path):
        manifest = load_manifest(path)
        if not manifest.get("complete"):
            raise CheckpointError(
                f"{path}: incomplete checkpoint (manifest lacks the "
                f"'complete' marker — interrupted legacy save?)")
        flat_like = _flatten(like)
        missing = set(flat_like) - set(manifest["params"])
        extra = set(manifest["params"]) - set(flat_like)
        if missing or extra:
            raise ValueError(
                f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                f"extra={sorted(extra)[:5]}")
        loaded = {}
        for key, meta in manifest["params"].items():
            fpath = os.path.join(path, meta["file"])
            if verify and "sha256" in meta:
                digest = _sha256(fpath)
                if digest != meta["sha256"]:
                    raise CheckpointError(
                        f"{path}: checksum mismatch for {key} "
                        f"({meta['file']}): {digest[:12]} != "
                        f"{meta['sha256'][:12]}")
            arr = np.load(fpath)
            want = flat_like[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"{key}: shape {arr.shape} != {tuple(want.shape)}")
            if meta["dtype"] != str(want.dtype):
                raise ValueError(
                    f"{key}: manifest dtype {meta['dtype']} != "
                    f"{want.dtype} in the restore target — refusing the "
                    f"silent cast (only the internal bf16<->f32 storage "
                    f"round-trip is coerced)")
            # bf16 leaves were stored as f32 files: cast back (the one
            # allowed coercion; dtype equality above already held)
            loaded[key] = (arr if str(arr.dtype) == meta["dtype"]
                           else arr.astype(want.dtype))
        tree = _unflatten_like(like, loaded, "")
        if placement is not None:
            from repro.placement.migrate import from_logical
            tree = from_logical(tree, placement)
        return tree


def _unflatten_like(like: Any, flat: dict, prefix: str) -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(like[k], flat, f"{prefix}{k}/") for k in like}
    if hasattr(like, "_fields"):
        return type(like)(*(_unflatten_like(getattr(like, k), flat, f"{prefix}{k}/")
                            for k in like._fields))
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_like(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    return flat[prefix[:-1]]


def step_path(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def complete_steps(root: str) -> list:
    """``[(step, path)]`` of *complete* checkpoints under ``root``, sorted
    numerically (``step_9`` < ``step_10000`` — no lexicographic trap).
    Directories with a missing/unreadable manifest or without the
    ``"complete"`` marker are skipped: a torn write never wins."""
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        m = _STEP_RE.match(d)
        p = os.path.join(root, d)
        if m is None or not os.path.isdir(p) or not is_complete(p):
            continue
        out.append((int(m.group(1)), p))
    return sorted(out)


def latest_step(root: str) -> str | None:
    """Path of the newest *complete* checkpoint under ``root`` (or None)."""
    steps = complete_steps(root)
    return steps[-1][1] if steps else None


def gc_checkpoints(root: str, *, keep: int = 3) -> list:
    """Remove all but the newest ``keep`` complete checkpoints, plus any
    stale ``.tmp-*`` dirs from crashed saves.  Returns removed paths."""
    removed = []
    if not os.path.isdir(root) or keep < 1:
        return removed
    for n, p in complete_steps(root)[:-keep]:
        shutil.rmtree(p)
        removed.append(p)
    pid_suffix = f".{os.getpid()}"
    for d in os.listdir(root):
        p = os.path.join(root, d)
        if (d.startswith(".tmp-") and os.path.isdir(p)
                and not d.endswith(pid_suffix)):  # not this process's live tmp
            shutil.rmtree(p)
            removed.append(p)
    return removed
