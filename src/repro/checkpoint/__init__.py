from repro.checkpoint.ckpt import (CheckpointError, complete_steps,
                                   gc_checkpoints, is_complete, latest_step,
                                   load_manifest, restore, save, step_path)

__all__ = ["CheckpointError", "complete_steps", "gc_checkpoints",
           "is_complete", "latest_step", "load_manifest", "restore", "save",
           "step_path"]
