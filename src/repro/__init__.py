"""repro: FastMoE (He et al., 2021) as a TPU-native JAX framework.

Public API re-exports; see README.md for the tour.
"""
__version__ = "0.1.0"

from repro.configs import ARCHS, ASSIGNED, INPUT_SHAPES, get_config, reduced  # noqa: F401
from repro.core.fmoe import DistConfig, fmoe_apply, fmoe_init  # noqa: F401
from repro.core.fmoefy import fmoefy  # noqa: F401
