import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, and fits — without hardware.

For each combination this driver builds the production mesh (16x16 single
pod, 2x16x16 multi-pod), constructs ShapeDtypeStruct stand-ins for every
input (no allocation), lowers + compiles the right step function
(train_step / prefill forward / serve decode_step), and records
memory_analysis + cost_analysis + the HLO collective schedule for
EXPERIMENTS.md §Dry-run and §Roofline.

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init.
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import cache_len_for, jit_serve_step, make_serve_step
from repro.launch.sharding import batch_spec, cache_specs, tree_shardings
from repro.launch.train import jit_train_step, moe_dist
from repro.models import lm
from repro.optim import AdamW

F32 = jnp.float32
I32 = jnp.int32


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for the data inputs of this shape."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), I32)}
        if cfg.frontend == "vision":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder.num_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    return {"tokens": jax.ShapeDtypeStruct((B, 1), I32),
            "pos": jax.ShapeDtypeStruct((), I32)}


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_combo(cfg: ModelConfig, shape: InputShape, mesh, opts=None):
    """Returns (lowered, n_devices).  Picks the step function by shape.mode."""
    rng = jax.random.PRNGKey(0)
    rcfg = cfg if (opts or {}).get("head_aware") else None
    params_shape = jax.eval_shape(lambda: lm.init_params(rng, cfg))
    pshard = tree_shardings(params_shape, mesh, cfg=rcfg)
    data = input_specs(cfg, shape)
    B, S = shape.global_batch, shape.seq_len

    if shape.mode == "train":
        opt = AdamW()
        jitted, pshard, oshard = jit_train_step(cfg, opt, mesh, B, S, opts=opts)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        return jitted.lower(_sds(params_shape), _sds(opt_shape), data,
                            jax.ShapeDtypeStruct((), I32))

    if shape.mode == "prefill":
        dist = moe_dist(cfg, mesh, B * S, opts=opts)

        def prefill(params, batch):
            logits, _ = lm.forward(params, cfg, batch["tokens"],
                                   frames=batch.get("frames"),
                                   patches=batch.get("patches"), dist=dist)
            return logits
        bshard = {k: jax.sharding.NamedSharding(
            mesh, batch_spec(B, mesh, len(v.shape) - 1)) for k, v in data.items()}
        jitted = jax.jit(prefill, in_shardings=(pshard, bshard))
        return jitted.lower(_sds(params_shape), data)

    # decode
    jitted, cache_shape = jit_serve_step(cfg, mesh, B, S, opts=opts)
    return jitted.lower(_sds(params_shape), data["tokens"], data["pos"],
                        _sds(cache_shape))


def lower_layer_probe(cfg: ModelConfig, shape: InputShape, mesh, opts=None):
    """Single-layer probe (the "B program" of the roofline decomposition).

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so the full program (scan over L layers) under-reports per-layer
    FLOPs/bytes/collectives by ~L.  We therefore lower one layer standalone —
    with the kv-chunk scan disabled so attention is fully visible — and
    combine: total = full_program + (L-1) * layer_probe (see roofline.py).
    Train mode probes grad-of-remat(layer) so backward + recompute count.
    """
    import repro.models.attention as A
    import repro.models.blocks as B
    from repro.core.balance import MoEMetrics
    from repro.models.lm import _cast_params

    opts = dict(opts or {})
    mp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if shape.mode == "decode" and shape.global_batch < mp and cfg.moe is None:
        opts.pop("serve_tp", None)  # mirror jit_serve_step's tiny-batch policy
        opts.pop("head_aware", None)
    rngp = jax.random.PRNGKey(0)
    layer_shape = jax.eval_shape(
        lambda: B.layer_init(rngp, cfg, cross=cfg.family == "audio"))
    from repro.launch.sharding import tree_specs
    from repro.launch.sharding import option_overrides
    pmode = "serve" if (shape.mode == "decode" and opts.get("serve_tp")) else "train"
    with option_overrides(opts, mesh):
        pspec = tree_specs(layer_shape, mesh, pmode,
                           cfg if opts.get("head_aware") else None)
    pshard = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), pspec,
                          is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    Bsz, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    window = B.FULL_WINDOW if (cfg.attention is None or
                               cfg.attention.sliding_window is None) \
        else cfg.attention.sliding_window

    if shape.mode in ("train", "prefill"):
        dist = moe_dist(cfg, mesh, Bsz * S, opts=opts)
        x_sds = jax.ShapeDtypeStruct((Bsz, S, cfg.d_model), dtype)
        xshard = jax.sharding.NamedSharding(mesh, batch_spec(Bsz, mesh, 2))

        def fwd(p_l, x):
            state0 = B.mixer_state(cfg, Bsz, dtype)
            y, m = B.layer_apply_seq(_cast_params(p_l, dtype), cfg, x,
                                     window=window, dist=dist,
                                     mixer_state=state0)
            loss = y.astype(jnp.float32).sum()
            if m is not None:
                loss = loss + m.aux_loss
            return loss

        if shape.mode == "train":
            inner = fwd if opts.get("no_remat") else jax.remat(fwd)
            f = jax.value_and_grad(inner, argnums=(0, 1))
            # pin cotangent shardings to the primal layouts — otherwise SPMD
            # replicates the dx output (a full-batch f32 all-reduce that the
            # real scanned program never performs)
            rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            out_shardings = (rep, (pshard, xshard))
        else:
            f = fwd
            out_shardings = None
        jitted = jax.jit(f, in_shardings=(pshard, xshard),
                         out_shardings=out_shardings)
        with A.chunk_override(S):
            return jitted.lower(_sds(layer_shape), x_sds)

    # decode probe
    dist = moe_dist(cfg, mesh, Bsz, opts=opts)
    clen = cache_len_for(cfg, S)
    cache_shape = jax.eval_shape(
        lambda: B.layer_cache(cfg, Bsz, clen, dtype))
    cshard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        cache_specs(cache_shape, mesh, Bsz,
                    seq_shard=bool(opts.get("cache_seq"))),
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    x_sds = jax.ShapeDtypeStruct((Bsz, 1, cfg.d_model), dtype)
    xshard = jax.sharding.NamedSharding(mesh, batch_spec(Bsz, mesh, 2))
    w_eff = min(window, clen)

    def dec(p_l, x, pos, cache):
        y, new_cache, _ = B.layer_apply_decode(
            _cast_params(p_l, dtype), cfg, x, cache, pos, window=w_eff,
            dist=dist)
        return y, new_cache

    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    jitted = jax.jit(dec, in_shardings=(pshard, xshard, rep, cshard))
    return jitted.lower(_sds(layer_shape), x_sds,
                        jax.ShapeDtypeStruct((), I32), _sds(cache_shape))


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str | None = None, opts: dict | None = None,
            tag: str = "") -> dict:
    import dataclasses
    cfg = get_config(arch)
    if (opts or {}).get("no_remat"):
        cfg = dataclasses.replace(cfg, remat="none")
    for k in list(opts or {}):  # "cf_<x>": override MoE capacity factor
        if k.startswith("cf_") and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(k[3:])))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "opts": opts or {}}
    t0 = time.time()
    import contextlib
    import repro.models.attention as _A

    def sdt_ctx():  # fresh context per use (generator CMs are single-shot)
        return (_A.score_dtype(jnp.bfloat16) if (opts or {}).get("attn_bf16")
                else contextlib.nullcontext())
    try:
        with sdt_ctx():
            lowered = lower_combo(cfg, shape, mesh, opts=opts)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        rl_full = R.analyze(compiled, n_devices=n_dev,
                            model_flops=R.model_flops_for(cfg, shape))
        # layer probe: recover the (L-1) scanned layers that XLA's
        # trip-count-blind cost analysis leaves out of the full program
        t2 = time.time()
        with sdt_ctx():
            probe = lower_layer_probe(cfg, shape, mesh, opts=opts).compile()
        rec["probe_s"] = round(time.time() - t2, 1)
        rl_layer = R.analyze(probe, n_devices=n_dev)
        rl = R.combine(rl_full, rl_layer, cfg.num_layers - 1)
        rec["roofline"] = rl.as_dict()
        rec["roofline_full_program_only"] = rl_full.as_dict()
        rec["roofline_per_layer"] = rl_layer.as_dict()
        rec["ok"] = True
    except Exception as e:  # a failure here is a bug in the system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma list of §Perf flags: expert_tp,constrain_tokens,serve_tp")
    ap.add_argument("--tag", default="", help="suffix for output JSON files")
    args = ap.parse_args()
    opts = {k: True for k in args.opts.split(",") if k}

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_one(arch, shape, multi_pod=mp, out_dir=args.out,
                              opts=opts, tag=args.tag)
                if rec["ok"]:
                    rl = rec["roofline"]
                    print(f"OK   {arch:18s} {shape:12s} {rec['mesh']:8s} "
                          f"comp={rl['compute_s']:.3e}s mem={rl['memory_s']:.3e}s "
                          f"coll={rl['collective_s']:.3e}s dom={rl['dominant']:10s} "
                          f"({rec['total_s']}s)", flush=True)
                else:
                    n_fail += 1
                    print(f"FAIL {arch:18s} {shape:12s} {rec['mesh']:8s} "
                          f"{rec['error'][:160]}", flush=True)
    print(f"failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
