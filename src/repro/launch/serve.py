"""Serving driver: jitted one-token decode step against a sharded KV/state
cache, plus a simple batched generation loop for the example/CLI.

Decode shapes (decode_32k / long_500k) lower THIS step, not train_step.
long_500k on full-attention archs runs the sliding-window variant: the ring
cache is capped at SWA_CAP and per-layer windows are clamped (DESIGN.md §4).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes
from repro.launch.sharding import batch_spec, cache_specs, tree_shardings
from repro.launch.train import moe_dist
from repro.models import lm

SWA_CAP = 8192  # ring-buffer cap for the long_500k sliding-window variant


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Ring length: full seq when it fits the attention pattern, else the
    sliding window (long_500k)."""
    if cfg.family == "ssm":
        return 1  # pure recurrent state; ring unused
    a = cfg.attention
    if seq_len > 32768:
        w = a.sliding_window if a.sliding_window else SWA_CAP
        return min(seq_len, max(w, 1))
    if a is not None and a.sliding_window:
        return min(seq_len, max(a.sliding_window,
                                1 if not a.global_layers else seq_len))
    return seq_len


def make_serve_step(cfg: ModelConfig, *, dist=None, with_metrics: bool = False,
                    paged: bool = False, layer_loads: bool = False):
    """Build the one-token serve step.  Returns a function with a FIXED
    3-tuple result ``(logits, cache, metrics)`` — ``metrics`` is ``{}`` when
    neither ``with_metrics`` nor ``layer_loads`` asks for telemetry, so call
    sites never branch on arity.

    ``with_metrics`` fills the dict with scalar decode telemetry (drop_frac
    + the repro.obs wire/drop/shadow counters, summed over layers like
    training's loss_fn aux) — same trace, no extra syncs.  ``layer_loads``
    adds ``load_layers`` (the (L, E) per-layer expert-load stack) and
    ``load`` — the online serve-time replan feed the continuous batcher
    pipes into ``LoadMonitor``.  ``paged=True`` takes a fifth argument, the
    (B, nb) per-slot block tables, and decodes through the paged pool
    (lm.init_paged_cache)."""
    L = max(cfg.num_layers, 1)

    def _pack(m, loads):
        md = {}
        if with_metrics:
            md["drop_frac"] = m.drop_frac / L
            if m.obs is not None:
                md.update(wire_elems=m.obs.wire_elems,
                          wire_bytes=m.obs.wire_bytes,
                          wire_bytes_intra=m.obs.wire_bytes_intra,
                          wire_bytes_inter=m.obs.wire_bytes_inter,
                          dropped=m.obs.dropped, shadow_hits=m.obs.shadow_hits,
                          imbalance=m.obs.imbalance / L)
        if layer_loads:
            md["load_layers"] = loads
            md["load"] = m.load / L
        return md

    if paged:
        def serve_step(params, tokens, pos, cache, block_tables):
            res = lm.decode_step(params, cfg, tokens, pos, cache, dist=dist,
                                 block_tables=block_tables,
                                 layer_loads=layer_loads)
            logits, new_cache, m = res[:3]
            return logits, new_cache, _pack(m, res[3] if layer_loads else None)
    else:
        def serve_step(params, tokens, pos, cache):
            res = lm.decode_step(params, cfg, tokens, pos, cache, dist=dist,
                                 layer_loads=layer_loads)
            logits, new_cache, m = res[:3]
            return logits, new_cache, _pack(m, res[3] if layer_loads else None)
    return serve_step


def jit_serve_step(cfg: ModelConfig, mesh, batch: int, seq_len: int, *,
                   opts: dict | None = None, with_metrics: bool = False):
    """Sharding-annotated decode step for the production mesh.

    opts["serve_tp"] keeps weights TP-resident (no FSDP over data) — at
    inference there are no optimizer states, so bf16 weights fit sharded over
    the model axis only and the per-layer weight all-gathers vanish (§Perf).

    opts["placement"] is an ExpertPlacement or PerLayerPlacement whose
    physical order ``params`` must already be in (placement.from_logical):
    decode usually runs the psum expert mode, where a plan load-balances the
    owned experts across ranks and serves shadowed hot experts locally,
    outside the reduction (core/fmoe._moe_psum) — the same load-balance loop
    as training, on the serving path.  Param/cache shardings are unchanged
    (a placement permutes content, not shapes).
    """
    opts = dict(opts or {})
    mp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if batch < mp and cfg.moe is None:
        # tiny-batch decode (long_500k) on dense archs: weight reads
        # dominate, so maximal (FSDP) weight sharding beats TP-residency and
        # head-aware replication — measured 0.1-0.8x regressions otherwise.
        # MoE archs keep the flags (expert weights are model-sharded either
        # way and head-aware still pays: arctic/deepseek ~4x even at B=1).
        opts.pop("serve_tp", None)
        opts.pop("head_aware", None)
    mode = "serve" if opts.get("serve_tp") else "train"
    clen = cache_len_for(cfg, seq_len)
    cache_shape = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, batch, clen))
    cshard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        cache_specs(cache_shape, mesh, batch,
                    seq_shard=bool(opts.get("cache_seq"))),
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    params_shape = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    rcfg = cfg if opts.get("head_aware") else None
    pshard = tree_shardings(params_shape, mesh, mode, cfg=rcfg)
    tshard = jax.sharding.NamedSharding(mesh, batch_spec(batch, mesh))
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    dist = moe_dist(cfg, mesh, batch, opts=opts)
    fn = make_serve_step(cfg, dist=dist, with_metrics=with_metrics)
    return jax.jit(fn, in_shardings=(pshard, tshard, rep, cshard),
                   out_shardings=(None, cshard, None),
                   donate_argnums=(3,)), cache_shape


def decode_dist(cfg: ModelConfig, mesh, batch: int, *,
                opts: dict | None = None):
    """Expert-parallel config for the continuous-batching decode loop,
    pinned to the **psum** mode.

    Placement-engaged psum decode is bitwise layout-invariant (per-slot
    combine before the fixed-order k-sum — README "Decode-time shadowing"),
    which is the property that makes mid-traffic replans safe: the same
    stream decoded under any plan yields identical tokens.  ``moe_dist``
    would pick a2a whenever the slot count happens to divide the mesh, and
    a2a capacity buffers are *not* layout-invariant, so the serving loop
    asks for psum explicitly — at decode's 1-token-per-slot scale the
    exchange would be latency-bound anyway.
    """
    d = moe_dist(cfg, mesh, batch, opts=opts)
    if d is None or d.mode == "psum":
        return d
    tok = tuple(a for a in d.token_axes if a not in d.expert_axes)
    total = 1
    for a in tok:
        total *= mesh.shape[a]
    if total > 1 and batch % total:
        tok = ()
    return d._replace(token_axes=tok)


def jit_paged_serve_step(cfg: ModelConfig, mesh, batch: int, num_blocks: int,
                         block_size: int, *, opts: dict | None = None,
                         with_metrics: bool = False,
                         layer_loads: bool = False):
    """Sharding-annotated paged decode step (continuous batching).

    The pool (lm.init_paged_cache) is shared by every decode slot, so it
    replicates over data axes with only head/latent dims model-sharded
    (cache_specs(paged=True)); block tables are small host-built (B, nb)
    int32 arrays and ride in replicated.  The MoE mode is pinned to psum
    (``decode_dist``) so serve-time replans stay bitwise-invisible.
    Returns ``(jitted_fn, pool_shape)``; the fn is
    ``(params, tokens, pos, pool, tables) -> (logits, pool, metrics)`` with
    the pool donated."""
    opts = dict(opts or {})
    mode = "serve" if opts.get("serve_tp") else "train"
    pool_shape = jax.eval_shape(
        functools.partial(lm.init_paged_cache, cfg, num_blocks, block_size))
    cshard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        cache_specs(pool_shape, mesh, batch, paged=True),
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    params_shape = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    rcfg = cfg if opts.get("head_aware") else None
    pshard = tree_shardings(params_shape, mesh, mode, cfg=rcfg)
    tshard = jax.sharding.NamedSharding(mesh, batch_spec(batch, mesh))
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    dist = decode_dist(cfg, mesh, batch, opts=opts)
    fn = make_serve_step(cfg, dist=dist, with_metrics=with_metrics,
                         paged=True, layer_loads=layer_loads)
    return jax.jit(fn, in_shardings=(pshard, tshard, rep, cshard, rep),
                   out_shardings=(None, cshard, None),
                   donate_argnums=(3,)), pool_shape


def generate(params, cfg: ModelConfig, prompt: jax.Array, steps: int, *,
             cache_len: int = 256, temperature: float = 0.0,
             rng=None, use_prefill: bool = True) -> jax.Array:
    """Greedy/temperature sampling loop.

    ``use_prefill=True`` runs ONE full forward pass over the prompt to fill
    the cache (serving fast path); otherwise the prompt is consumed token by
    token (useful as a cross-check — tests assert both paths agree)."""
    B, S = prompt.shape
    cache = lm.init_cache(cfg, B, cache_len)
    step = jax.jit(functools.partial(lm.decode_step, cfg=cfg))

    def sample(logits_last, rng):
        if temperature > 0 and rng is not None:
            rng, k = jax.random.split(rng)
            return jax.random.categorical(
                k, logits_last / temperature)[:, None].astype(jnp.int32), rng
        return jnp.argmax(logits_last, -1)[:, None].astype(jnp.int32), rng

    out = [prompt]
    if use_prefill:
        logits, cache, _ = jax.jit(
            functools.partial(lm.prefill, cfg=cfg))(params, tokens=prompt,
                                                    cache=cache)
        tok, rng = sample(logits[:, -1], rng)
        start = S
    else:
        tok = prompt[:, :1]
        out = [tok]
        for pos in range(S - 1):
            logits, cache, _ = step(params, tokens=prompt[:, pos:pos + 1],
                                    pos=jnp.int32(pos), cache=cache)
            out.append(prompt[:, pos + 1:pos + 2])
        logits, cache, _ = step(params, tokens=prompt[:, S - 1:S],
                                pos=jnp.int32(S - 1), cache=cache)
        tok, rng = sample(logits[:, -1], rng)
        start = S
    out.append(tok)
    for pos in range(start, S + steps - 1):
        logits, cache, _ = step(params, tokens=tok, pos=jnp.int32(pos),
                                cache=cache)
        tok, rng = sample(logits[:, -1], rng)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def plan_for_serving(params, cfg: ModelConfig, prompt: jax.Array,
                     num_ranks: int, *, per_layer: bool = True):
    """Measure per-layer expert load on the prompt and plan a decode layout.

    One forward pass over the prompt yields the (L, E) load stack; the
    planner (train=False: no grad all-reduce to charge for) picks each
    layer's permutation.  Returns ``(plan, params)`` with params migrated
    into the plan's physical order.

    Expect ``num_shadow == 0`` from this path: the decode mode is psum,
    where shadowing saves no wire bytes and replicates weight reads, so the
    cost model correctly declines it — the per-layer *permutation* is what
    pays at decode (balanced owned compute).  The decode-time shadow
    execution in ``core/fmoe._moe_psum`` is there for the other direction:
    a shadowed plan produced by the *training* loop (ReplanHook /
    checkpoint restore) serves unchanged, bit-identically to its
    unshadowed twin, instead of forcing a re-migration at deploy time.
    """
    import numpy as np

    from repro.core.dispatch import expert_capacity
    from repro.placement import (from_logical, load_calibration,
                                 plan_placement, plan_placement_per_layer)

    moe = cfg.moe
    _, _, loads = lm.forward(params, cfg, prompt, layer_loads=True)
    cap = expert_capacity(prompt.shape[0], moe.num_experts, moe.top_k,
                          moe.capacity_factor)
    # train=False: no grad all-reduce to charge; shrink_capacity=False: the
    # decode path is psum — no a2a buffer exists, so a shrunk capacity would
    # only add decode-time drops (and _moe_psum ignores the shrink anyway)
    kw = dict(d_model=cfg.d_model, d_hidden=moe.d_expert_hidden,
              capacity=cap, capacity_factor=moe.capacity_factor,
              train=False, shrink_capacity=False,
              constants=load_calibration())
    if per_layer:
        plan = plan_placement_per_layer(np.asarray(loads), num_ranks, **kw)
    else:
        plan = plan_placement(np.asarray(loads).sum(0), num_ranks, **kw)
    return plan, from_logical(params, plan)


def serve_continuous(params, cfg: ModelConfig, scfg, *, prompt_len: int,
                     gen: int, num_requests: int, sink=None) -> None:
    """Drive the continuous-batching engine (launch/scheduler) over a
    synthetic request stream described by the CLI flags and print the
    serving headline numbers (tokens/sec, per-token p50/p99)."""
    import numpy as np

    from repro.launch.scheduler import ContinuousBatcher
    from repro.launch.serve_api import Request

    rng = np.random.RandomState(1)
    batcher = ContinuousBatcher(params, cfg, scfg, sink=sink)
    t0 = time.time()
    for i in range(num_requests):
        s = max(1, prompt_len - int(rng.randint(0, max(prompt_len // 2, 1))))
        batcher.submit(Request(
            id=i, prompt=rng.randint(0, cfg.vocab_size, s).astype(np.int32),
            max_new_tokens=gen))
    batcher.run()
    dt = time.time() - t0
    done = batcher.completions
    toks = sum(len(c.tokens) for c in done)
    lats = sorted(l for c in done for l in c.latencies[1:]) or [0.0]
    print(f"continuous: {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s) over {batcher.ticks} ticks; "
          f"per-token p50 {lats[len(lats) // 2] * 1e3:.1f}ms "
          f"p99 {lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3:.1f}ms; "
          f"replans={batcher.replans}")


def main() -> None:
    from repro.launch.serve_api import ServeConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode width: the static demo's batch, and the "
                         "slot count when --slots is not given")
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL mesh for the sharded decode step (e.g. "
                         "1x4; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--continuous", action="store_true",
                    help="run the continuous-batching serve loop "
                         "(launch/scheduler: per-step admit/retire, paged KV "
                         "cache, online replans) over a synthetic request "
                         "stream instead of decoding one static batch")
    ap.add_argument("--requests", type=int, default=0,
                    help="request count for --continuous (0 = 3x slots)")
    ap.add_argument("--slots", type=int, default=None,
                    help="decode slots (ServeConfig.slots; default --batch)")
    ap.add_argument("--block_size", type=int, default=None,
                    help="paged KV cache block rows (ServeConfig.block_size)")
    ap.add_argument("--max_len", type=int, default=None,
                    help="per-request prompt+gen cap (ServeConfig.max_len; "
                         "default prompt_len + gen)")
    ap.add_argument("--policy", default=None,
                    choices=["continuous", "static"],
                    help="admission policy for --continuous (static = "
                         "admit only at whole-batch boundaries)")
    ap.add_argument("--replan_every", type=int, default=None,
                    help="decode ticks between online placement-replan "
                         "polls (0 = off; needs --mesh and an MoE arch)")
    ap.add_argument("--per_layer_plans", action="store_true",
                    help="measure per-layer expert load on the prompt and "
                         "serve under a per-layer placement (decode-time "
                         "shadowing; needs --mesh and an MoE arch)")
    ap.add_argument("--metrics_out", default="",
                    help="write per-decode-step telemetry (JSONL): latency, "
                         "tokens/sec, device-side wire/drop/shadow counters "
                         "(repro.obs; needs --mesh)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace of host-side decode_step "
                         "spans (chrome://tracing / perfetto)")
    ap.add_argument("--router", default="",
                    choices=["", "topk", "noisy_topk", "gumbel",
                             "expert_choice", "frozen"],
                    help="override the MoE routing variant for serving "
                         "(all routers are deterministic at decode: no rng "
                         "is threaded, so gumbel == topk here)")
    args = ap.parse_args()

    scfg = ServeConfig.from_args(args)
    if args.max_len is None:
        scfg.max_len = args.prompt_len + args.gen

    from repro.obs import JsonlSink
    from repro.obs import trace as obs_trace
    sink = JsonlSink(scfg.metrics_out) if scfg.metrics_out else None
    if scfg.trace:
        obs_trace.configure(enabled=True)

    cfg = get_config(scfg.arch)
    if scfg.reduced:
        cfg = reduced(cfg, num_layers=4, d_model=256)
    if args.router and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=args.router))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    if args.continuous:
        n_req = args.requests or 3 * scfg.slots
        serve_continuous(params, cfg, scfg, prompt_len=args.prompt_len,
                         gen=args.gen, num_requests=n_req, sink=sink)
        if sink is not None:
            sink.close()
            print(f"metrics written to {scfg.metrics_out}")
        if scfg.trace:
            obs_trace.export(scfg.trace)
            print(f"trace written to {scfg.trace}")
        return
    if args.mesh:
        from repro.launch.mesh import make_local_mesh
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = make_local_mesh(d, m)
        opts: dict = {}
        if args.per_layer_plans and cfg.moe is not None and m > 1:
            plan, params = plan_for_serving(params, cfg, prompt, m,
                                            per_layer=True)
            opts["placement"] = plan
            print(f"serving plan: shadow={plan.num_shadow} "
                  f"cap_scale={plan.capacity_scale:.2f}")
        seq_len = args.prompt_len + args.gen
        step, _ = jit_serve_step(cfg, mesh, args.batch, seq_len, opts=opts,
                                 with_metrics=sink is not None)
        cache = lm.init_cache(cfg, args.batch, cache_len_for(cfg, seq_len))
        tok, out = prompt[:, :1], [prompt[:, :1]]
        telemetry = sink is not None or obs_trace.enabled()
        lat: list = []
        t0 = time.time()
        with mesh:
            for pos in range(seq_len - 1):
                ts = time.time()
                with obs_trace.span("decode_step", pos=pos):
                    logits, cache, md = step(params, tok, jnp.int32(pos), cache)
                    if telemetry:  # real per-step latency, not dispatch time
                        jax.block_until_ready(logits)
                lat.append(time.time() - ts)
                if sink is not None:
                    rec = {"kind": "decode_step", "pos": pos,
                           "wall_s": lat[-1],
                           "tokens_per_s": args.batch / max(lat[-1], 1e-9)}
                    rec.update({k: float(v) for k, v in md.items()})
                    sink.emit(rec)
                tok = (prompt[:, pos + 1:pos + 2] if pos + 1 < args.prompt_len
                       else jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32))
                out.append(tok)
        seq = jnp.concatenate(out, axis=1)
        if len(lat) > 1:
            # steady-state decode latency (skip step 0: it pays the compile)
            srt = sorted(lat[1:])
            p50 = srt[len(srt) // 2]
            p99 = srt[min(len(srt) - 1, int(len(srt) * 0.99))]
            print(f"decode: {len(lat)} steps, p50 {p50 * 1e3:.1f}ms "
                  f"p99 {p99 * 1e3:.1f}ms")
    else:
        t0 = time.time()
        seq = generate(params, cfg, prompt, args.gen)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(seq[0])
    if sink is not None:
        sink.close()
        print(f"metrics written to {args.metrics_out}")
    if args.trace:
        obs_trace.export(args.trace)
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
