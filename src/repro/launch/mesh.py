"""Device meshes.

``make_production_mesh`` targets the TPU v5e deployment: one pod = 256 chips
as (data=16, model=16); multi-pod adds a leading "pod" axis (2 pods = 512).
Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` *before* first jax init.

Mesh construction goes through ``repro.compat`` so the ``axis_types`` kwarg
(absent on jax 0.4.x) degrades to a plain ``Mesh``.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, node: int = 1):
    """Small mesh over however many (fake) devices a test process has.

    ``node > 1`` inserts a "node" axis between data and model: expert
    parallelism then spans ("node", "model") and the ragged exchange runs
    two-level — aggregate within the node-local "model" axis, slim exchange
    over the inter-node "node" axis (core/fmoe DistConfig.node_axis).
    """
    if node > 1:
        return compat.make_mesh((data, node, model), ("data", "node", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def node_axis(mesh):
    """The inter-node mesh axis name, or None for a single-level mesh."""
    return "node" if "node" in mesh.axis_names else None


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)
