"""Device meshes.

``make_production_mesh`` targets the TPU v5e deployment: one pod = 256 chips
as (data=16, model=16); multi-pod adds a leading "pod" axis (2 pods = 512).
Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` *before* first jax init.

Mesh construction goes through ``repro.compat`` so the ``axis_types`` kwarg
(absent on jax 0.4.x) degrades to a plain ``Mesh``.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake) devices a test process has."""
    return compat.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)
