"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds-per-step on TPU v5e
constants:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum_c  bytes(c) * hops(c) / ICI_BW      (parsed from HLO text)

HLO after SPMD partitioning is per-device, so cost_analysis numbers are
already per-chip.  Collective bytes are not in cost_analysis; we parse the
optimized HLO and sum output-operand sizes of every collective op, weighting
all-reduce x2 (reduce + broadcast phases of a ring).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],{}/ ]+?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.IGNORECASE)
# XLA writes /*index=N*/ comments inside wide tuple shapes (e.g. the
# tuple-form all-to-all a multi-axis exchange lowers to); the '=' inside
# would cut the shape group short, so strip them before matching
_TUPLE_COMMENT_RE = re.compile(r"/\*index=\d+\*/")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_HOPS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type output bytes (per device) from optimized HLO."""
    out: dict = {}
    for m in _COLL_RE.finditer(_TUPLE_COMMENT_RE.sub("", hlo_text)):
        shapes, op = m.group(1), m.group(2).lower()
        out[op] = out.get(op, 0) + _shape_bytes(shapes)
    return out


@dataclass
class Roofline:
    flops: float  # per device
    hbm_bytes: float  # per device
    coll_bytes: dict  # per device, by op type
    n_devices: int
    model_flops: float = 0.0  # 6*N_active*D etc (global)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(b * _HOPS.get(op, 1.0) for op, b in self.coll_bytes.items()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def step_s(self) -> float:
        """Roofline step-time lower bound (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_s_bound": self.step_s,
        }


def analyze(compiled, *, n_devices: int, model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops, hbm, coll, n_devices, model_flops)


def combine(full: Roofline, layer: Roofline, extra_layers: int) -> Roofline:
    """total = full_program + extra_layers * layer_probe.

    XLA cost analysis counts while-loop bodies once, so the full program's
    numbers include ONE layer's worth of the scanned stack; the remaining
    (L-1) layers come from the standalone layer probe (which has no outer
    while and full-sequence attention chunks).
    """
    coll = dict(full.coll_bytes)
    for op, b in layer.coll_bytes.items():
        coll[op] = coll.get(op, 0) + extra_layers * b
    return Roofline(full.flops + extra_layers * layer.flops,
                    full.hbm_bytes + extra_layers * layer.hbm_bytes,
                    coll, full.n_devices, full.model_flops)


def model_flops_for(cfg, shape) -> float:
    """Paper-style useful-FLOPs estimate: 6*N_active*tokens (train) or
    2*N_active*tokens (inference)."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 new token/seq
