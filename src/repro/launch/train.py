"""Training driver: jitted train_step (with optional microbatch gradient
accumulation), sharding-aware jit wiring, and a CLI for real runs.

Usage (example, CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch fastmoe-gpt --steps 100 \
      --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.configs.base import ModelConfig
from repro.core.fmoe import DistConfig
from repro.data import SyntheticLM
from repro.launch.mesh import all_axes, data_axes, make_local_mesh
from repro.launch.sharding import batch_spec, tree_shardings
from repro.models import lm
from repro.obs import events as obs_events
from repro.optim import AdamW, warmup_cosine
from repro.resilience import (CheckpointManager, StepGuard, TrainingAborted,
                              faults)


def moe_dist(cfg: ModelConfig, mesh, num_tokens: int, *,
             opts: Optional[dict] = None) -> Optional[DistConfig]:
    """Pick the expert-parallel mode for this (config, mesh, token count).

    a2a (the paper's §3.2 exchange) when tokens split across every axis
    including the expert axis; psum otherwise (decode-time small batches);
    None when the config has no MoE or the mesh has no expert axis.
    ``opts`` toggles the §Perf beyond-paper optimizations (expert_tp,
    constrain_tokens) and may carry an ExpertPlacement or PerLayerPlacement
    under ``placement``, attached on every expert-parallel mode: the a2a
    paths skip shadowed experts on the wire, and the psum (decode) path
    balances owned experts per rank and serves shadowed ones outside the
    reduction (core/fmoe._moe_psum) — params must be in the plan's physical
    order either way.
    """
    opts = opts or {}
    if cfg.moe is None or "model" not in mesh.axis_names:
        return None
    expert_axis = "model"
    if (opts.get("expert_pod") and "pod" in mesh.axis_names
            and cfg.moe.num_experts
            % (mesh.shape["pod"] * mesh.shape["model"]) == 0):
        # §Perf multi-pod: expert parallelism spans pods (no cross-pod
        # expert-gradient sync; the a2a crosses pods instead)
        expert_axis = ("pod", "model")
    node_ax = None
    if ("node" in mesh.axis_names
            and cfg.moe.num_experts
            % (mesh.shape["node"] * mesh.shape["model"]) == 0):
        # hierarchical mesh (launch/mesh make_local_mesh(node=...)): expert
        # parallelism spans (node, model) node-major, and the ragged a2a
        # runs two-level — aggregate intra-node, slim inter-node exchange
        expert_axis = ("node", "model")
        node_ax = "node"
    ep = 1
    for a in (expert_axis if isinstance(expert_axis, tuple) else (expert_axis,)):
        ep *= mesh.shape[a]
    if cfg.moe.num_experts % ep:
        return None
    total = 1
    for a in mesh.axis_names:
        total *= mesh.shape[a]
    rb = opts.get("ragged_bound") or 0
    ib = int(opts.get("inter_bound") or 0)
    if rb == "auto":
        # adaptive bounds: size the static shards to the LoadMonitor's
        # measured peak peer share (drop-guarded; core/monitor
        # suggest_ragged_bound).  A cold monitor resolves to the dropless
        # default; ReplanHook re-jits through here, so every replan
        # re-calibrates the bounds to the current load EMAs.
        mon = opts.get("load_monitor")
        t_local = num_tokens // total if num_tokens % total == 0 else 0
        rb = 0
        if mon is not None and t_local:
            rb = mon.suggest_ragged_bound(t_local, cfg.moe.top_k, ep)
            if rb >= t_local * cfg.moe.top_k:
                rb = 0  # dropless: keep the canonical 0 spelling
            if node_ax and rb and not ib:
                # slim inter-node shards aggregate n_inner source ranks; the
                # peak is still one rank block's share of the pooled rows
                ib = mon.suggest_ragged_bound(
                    t_local * (ep // mesh.shape["node"]), cfg.moe.top_k, ep)
    extra = dict(
        expert_axis=expert_axis,
        tp_axis="data" if opts.get("expert_tp") and "data" in mesh.axis_names else None,
        constrain_tokens=bool(opts.get("constrain_tokens")),
        fsdp_axis="data" if (opts.get("constrain_tokens")
                             and "data" in mesh.axis_names) else None,
        overlap_chunks=int(opts.get("overlap_chunks") or 0),
        wire_dtype=opts.get("wire_dtype") or None,
        ragged_bound=int(rb),
        node_axis=node_ax,
        inter_bound=ib,
    )
    if num_tokens % total == 0:
        return DistConfig(mesh, all_axes(mesh), placement=opts.get("placement"),
                          **extra)
    d_axes = data_axes(mesh)
    dsize = 1
    for a in d_axes:
        dsize *= mesh.shape[a]
    # psum fallbacks: no a2a, so overlap_chunks / wire_dtype don't apply —
    # but a placement does (decode-time shadowing skips hot experts in the
    # psum reduction and serves them locally; see core/fmoe._moe_psum)
    if num_tokens % dsize == 0:
        return DistConfig(mesh, d_axes, expert_axis=expert_axis, tp_axis=None,
                          constrain_tokens=extra["constrain_tokens"],
                          placement=opts.get("placement"))
    return DistConfig(mesh, (), expert_axis=expert_axis, tp_axis=None,
                      constrain_tokens=extra["constrain_tokens"],
                      placement=opts.get("placement"))


def make_train_step(cfg: ModelConfig, opt: AdamW, *, dist=None,
                    num_microbatches: int = 1, warmup: int = 100,
                    total_steps: int = 10000, impl: str = "einsum"):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``impl`` picks the expert kernels (einsum | pallas | fused); "fused"
    runs the one-kernel FFN forward AND the fused dX/dW backward, so the
    step never materializes the (M, H) hidden activation in HBM.
    """

    # exploration routers perturb gate selection with a per-step key derived
    # from the step counter (deterministic, resume-stable); every other
    # router stays rng-free so existing runs are bit-identical
    explore = (cfg.moe is not None
               and cfg.moe.router in ("noisy_topk", "gumbel"))

    def grads_of(params, batch, rng=None):
        return jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, dist=dist, impl=impl,
                                 rng=rng),
            has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        rng = (jax.random.fold_in(jax.random.PRNGKey(17), step)
               if explore else None)
        if num_microbatches == 1:
            (loss, aux), grads = grads_of(params, batch, rng)
        else:
            def split(x):
                b = x.shape[0] // num_microbatches
                return x.reshape(num_microbatches, b, *x.shape[1:])
            micro = jax.tree.map(split, batch)
            rngs = (jax.random.split(rng, num_microbatches) if explore
                    else jnp.zeros((num_microbatches,), jnp.uint32))

            def body(acc, xs):
                mb, r = xs
                (l, a), g = grads_of(params, mb, r if explore else None)
                return jax.tree.map(jnp.add, acc, (g, l, a)), None

            zero_g = jax.tree.map(jnp.zeros_like, params)
            n_e = cfg.moe.num_experts if cfg.moe is not None else 1
            aux0 = {"ce": jnp.zeros(()), "aux_loss": jnp.zeros(()),
                    "z_loss": jnp.zeros(()), "drop_frac": jnp.zeros(()),
                    "load": jnp.zeros((n_e,)),
                    "load_layers": jnp.zeros((cfg.num_layers, n_e)),
                    # obs counters (repro.obs) emitted by loss_fn's aux
                    "wire_elems": jnp.zeros(()), "wire_bytes": jnp.zeros(()),
                    "wire_bytes_intra": jnp.zeros(()),
                    "wire_bytes_inter": jnp.zeros(()),
                    "dropped": jnp.zeros(()), "shadow_hits": jnp.zeros(()),
                    "imbalance": jnp.zeros(())}
            (grads, loss, aux), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros(()), aux0), (micro, rngs))
            inv = 1.0 / num_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, aux = loss * inv, jax.tree.map(lambda a: a * inv, aux)
        lr_scale = warmup_cosine(step, warmup=warmup, total=total_steps)
        params, opt_state, gnorm = opt.update(grads, opt_state, params,
                                              lr_scale=lr_scale)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr_scale": lr_scale, **aux}

    return train_step


def jit_train_step(cfg: ModelConfig, opt: AdamW, mesh, global_batch: int,
                   seq_len: int, *, num_microbatches: int = 1,
                   opts: Optional[dict] = None, placement=None):
    """Fully sharding-annotated jitted train step for ``mesh``.

    ``placement`` re-jits the step under a migrated expert layout (the
    replan hook swaps it while param/opt shardings stay identical).
    """
    from repro.launch.sharding import option_overrides
    opts = dict(opts or {})
    if placement is not None:
        opts["placement"] = placement
    rng = jax.random.PRNGKey(0)
    rcfg = cfg if opts.get("head_aware") else None
    with option_overrides(opts, mesh):
        params_shape = jax.eval_shape(lambda: lm.init_params(rng, cfg))
        pshard = tree_shardings(params_shape, mesh, cfg=rcfg)
        oshard_shape = jax.eval_shape(opt.init, params_shape)
        oshard = tree_shardings(oshard_shape, mesh, cfg=rcfg)
    bspec = {"tokens": jax.sharding.NamedSharding(mesh, batch_spec(global_batch, mesh))}
    if cfg.frontend == "vision":
        bspec["patches"] = jax.sharding.NamedSharding(mesh, batch_spec(global_batch, mesh, 2))
    if cfg.family == "audio":
        bspec["frames"] = jax.sharding.NamedSharding(mesh, batch_spec(global_batch, mesh, 2))
    dist = moe_dist(cfg, mesh, global_batch * seq_len, opts=opts)
    step_fn = make_train_step(cfg, opt, dist=dist,
                              num_microbatches=num_microbatches,
                              impl=opts.get("impl") or "einsum")
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.jit(
        step_fn,
        in_shardings=(pshard, oshard, bspec, rep),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    ), pshard, oshard


# ---------------------------------------------------------------------------
# Periodic replan-and-migrate hook (placement subsystem, paper §6 follow-on)
# ---------------------------------------------------------------------------


class ReplanHook:
    """Closes the load-balance loop: LoadMonitor -> PlacementController ->
    migrate params/opt state -> re-jit the train step under the new layout.

    Call :meth:`observe` every step with the step metrics; when the
    controller decides a better placement pays for its migration, the hook
    permutes the live param/optimizer trees (checkpoint-compatible — see
    repro.placement.migrate.to_logical) and returns a freshly jitted step.

    **Rollback** (ISSUE 8): every accepted replan opens a probation window
    (:class:`repro.resilience.ReplanProbation`).  If the post-replan loss
    or drop fraction regresses against the pre-replan EMA baselines, the
    migration is *inverted* — params/opt state permute back to the prior
    placement, the step re-jits under it, and the regressing plan is
    blacklisted in the controller so the cost model can never propose it
    again.  New replans are deferred while a probation is open (one
    experiment at a time).  Pass ``rollback=False`` to opt out.
    """

    def __init__(self, cfg: ModelConfig, opt: AdamW, mesh, global_batch: int,
                 seq_len: int, *, every: int = 200,
                 num_microbatches: int = 1, opts: Optional[dict] = None,
                 per_layer: bool = False, sink=None, rollback: bool = True,
                 probation: Optional[int] = None,
                 probation_loss_tol: float = 1.05,
                 probation_drop_tol: float = 0.05):
        from repro.core.dispatch import expert_capacity
        from repro.core.monitor import LoadMonitor
        from repro.placement import (PlacementController, identity_placement,
                                     load_calibration)

        self.cfg, self.opt, self.mesh = cfg, opt, mesh
        self.global_batch, self.seq_len = global_batch, seq_len
        self.num_microbatches, self.opts = num_microbatches, opts
        self.per_layer = per_layer
        moe = cfg.moe
        n_dev = 1
        for a in mesh.axis_names:
            n_dev *= mesh.shape[a]
        # a plan only executes if moe_dist threads it into the a2a path for
        # this (config, mesh, shape, opts) combo; otherwise migrating would
        # permute params under a step that never remaps gate ids.  Probe with
        # the SAME opts observe() will re-jit with, and size the controller
        # to the probe's actual expert parallelism (expert_pod may widen it).
        probe = moe_dist(cfg, mesh, global_batch * seq_len,
                         opts={**dict(opts or {}),
                               "placement": identity_placement(
                                   moe.num_experts, 1)})
        self.enabled = (probe is not None and probe.placement is not None
                        and probe.mode == "a2a")
        ranks = probe.expert_parallelism if self.enabled else 1
        # per-gate token count: the flat shard _moe_a2a sees per microbatch
        t_local = max(1, global_batch * seq_len // n_dev // num_microbatches)
        cap = expert_capacity(t_local, moe.num_experts, moe.top_k,
                              moe.capacity_factor)
        L = cfg.num_layers if per_layer else 0
        self.sink = sink  # optional repro.obs MetricsSink (replan events +
        # the monitor's sampled load snapshots land here)
        # updates arrive pre-sampled (every sync_every steps), so record each
        self.monitor = LoadMonitor(moe.num_experts, num_layers=L, sink=sink,
                                   record_every=1 if sink is not None else 0)
        # price plans with bandwidths measured on THIS machine when the
        # benchmark suite has left results behind (v5e roofline otherwise),
        # and with the bytes the wire actually moves under wire_dtype
        constants = load_calibration()
        wire_bytes = 2 if (opts or {}).get("wire_dtype") == "bf16" else 4
        self.controller = PlacementController(
            self.monitor, ranks, d_model=cfg.d_model,
            d_hidden=moe.d_expert_hidden, capacity=cap,
            capacity_factor=moe.capacity_factor,
            every=every if self.enabled else 0, bytes_per_elem=wire_bytes,
            num_layers=L, constants=constants)
        # fetch load to host only on sampled steps: a per-step device_get
        # would serialize host and device for a decision made every `every`
        self.sync_every = max(1, every // 16)
        from repro.resilience import ReplanProbation
        self.probation = (ReplanProbation(
            window=probation if probation else max(4, min(64, every // 4)),
            loss_tol=probation_loss_tol, drop_tol=probation_drop_tol,
            sink=sink) if rollback else None)
        # host-side loss/drop EMAs: the pre-replan baselines probation
        # judges against (fed by observe()'s loss=/drop= kwargs — the train
        # loop already holds those host floats for the step guard)
        self._loss_ema: Optional[float] = None
        self._drop_ema: Optional[float] = None

    @property
    def placement(self):
        return self.controller.current

    def _switch(self, step: int, old, new, params, opt_state, *,
                span: str = "replan"):
        """Re-jit under ``new`` and permute live state from ``old``'s
        physical order into ``new``'s (shared replan/rollback machinery)."""
        from repro.obs import trace as obs_trace
        from repro.placement import migrate

        with obs_trace.span(span, step=step):
            step_fn, pshard, oshard = jit_train_step(
                self.cfg, self.opt, self.mesh, self.global_batch, self.seq_len,
                num_microbatches=self.num_microbatches, opts=self.opts,
                placement=new)
            with obs_trace.span("migrate", step=step):
                params = jax.device_put(migrate(params, old, new), pshard)
                opt_state = jax.device_put(migrate(opt_state, old, new),
                                           oshard)
        return params, opt_state, step_fn

    def observe(self, step: int, metrics: dict, params, opt_state, *,
                loss: Optional[float] = None, drop: Optional[float] = None):
        """Returns (params, opt_state, new_step_fn | None).

        ``loss``/``drop`` are the step's host-side scalars when the caller
        already has them (the guarded train loop does); otherwise they are
        pulled from ``metrics`` where present.  They feed the probation
        baselines — without them rollback judges on whichever metric it has.
        """
        from repro.core.balance import MoEMetrics

        if (self.per_layer and self.controller.every
                and "load_layers" not in metrics and "load" in metrics):
            # fail loudly: falling back to the summed load would leave the
            # (L, E) EMA at its uniform init and the per-layer controller
            # would silently never replan
            raise ValueError(
                "ReplanHook(per_layer=True) needs metrics['load_layers'] "
                "(the (L, E) stack loss_fn emits); got only 'load'")
        if loss is None and "loss" in metrics:
            loss = float(metrics["loss"])
        if drop is None and "drop_frac" in metrics:
            drop = float(metrics["drop_frac"])
        ema = lambda old, v: v if old is None else 0.9 * old + 0.1 * v
        if loss is not None:
            self._loss_ema = ema(self._loss_ema, loss)
        if drop is not None:
            self._drop_ema = ema(self._drop_ema, drop)
        load_key = "load_layers" if self.per_layer else "load"
        if (load_key in metrics and self.controller.every
                and step % self.sync_every == 0):
            # device_get lands here (and only here) when metrics are device
            # arrays: the monitor EMA samples every sync_every-th step.
            # per-layer mode feeds the stacked (L, E) loads from loss_fn's
            # aux so each layer's skew drives its own plan.
            m = MoEMetrics(0.0, 0.0,
                           jax.device_get(metrics[load_key]),
                           jax.device_get(metrics.get("drop_frac", 0.0)))
            self.monitor.update(m)
        if self.probation is not None and self.probation.active:
            decision = self.probation.observe(step, loss=loss, drop=drop)
            if decision.rollback:
                params, opt_state, step_fn = self._switch(
                    step, decision.new_plan, decision.old_plan, params,
                    opt_state, span="replan_rollback")
                self.controller.rollback(decision.old_plan, decision.new_plan)
                print(f"step {step:5d} replan ROLLBACK: {decision.reason} "
                      f"(plan blacklisted)")
                return params, opt_state, step_fn
            if self.probation.active:  # still on probation: defer replans
                return params, opt_state, None
        old = self.controller.current
        new = self.controller.maybe_replan(step)
        if new is None:
            return params, opt_state, None
        params, opt_state, step_fn = self._switch(step, old, new, params,
                                                  opt_state)
        if self.probation is not None:
            # drop baseline defaults to 0: a replan must not *introduce*
            # drops even if the run never measured any before it
            self.probation.start(
                step, old, new, baseline_loss=self._loss_ema,
                baseline_drop=self._drop_ema if self._drop_ema is not None
                else 0.0)
        if self.sink is not None:
            self.sink.emit({"kind": "replan", "step": step,
                            "num_shadow": int(new.num_shadow),
                            "capacity_scale": float(new.capacity_scale),
                            "imbalance": self.monitor.imbalance})
        return params, opt_state, step_fn


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fastmoe-gpt")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced CPU-scale variant")
    ap.add_argument("--log_every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL mesh, e.g. 1x4, or DATAxNODExMODEL, "
                         "e.g. 1x2x4, for the hierarchical two-level ragged "
                         "exchange (requires that many devices; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--replan_every", type=int, default=0,
                    help="steps between expert-placement replans "
                         "(0 = off; needs --mesh and an MoE arch)")
    ap.add_argument("--per_layer_plans", action="store_true",
                    help="plan expert placement per layer (each layer gets "
                         "its own permutation + shadow set from its own "
                         "measured load; needs --replan_every)")
    ap.add_argument("--overlap_chunks", type=int, default=0,
                    help="§5.2 smart schedule: pipeline the expert all-to-all "
                         "with compute in this many capacity micro-shards "
                         "(0/1 = serial; needs --mesh and an MoE arch)")
    ap.add_argument("--wire_dtype", default="", choices=["", "bf16"],
                    help="cast a2a payloads across the wire (halves bytes)")
    ap.add_argument("--impl", default="einsum",
                    choices=["einsum", "pallas", "fused"],
                    help="expert kernels: einsum (batched XLA GEMMs), pallas "
                         "(two-pass grouped GEMMs), fused (one-kernel FFN "
                         "fwd+bwd — no (M, H) hidden in HBM)")
    ap.add_argument("--dispatch", default="", choices=["", "capacity", "ragged"],
                    help="override the MoE dispatch mode (ragged = dropless "
                         "sorted tokens; with --mesh it runs the ragged "
                         "load-sized all-to-all exchange)")
    ap.add_argument("--router", default="",
                    choices=["", "topk", "noisy_topk", "gumbel",
                             "expert_choice", "frozen"],
                    help="override the MoE routing variant (see "
                         "MoEConfig.router; expert_choice emits exact "
                         "per-expert capacities and a flat load)")
    ap.add_argument("--freeze_router_at", type=int, default=0,
                    help="StableMoE two-stage: at this step the live gate "
                         "stops routing and the distilled lightweight "
                         "router takes over (cfg flips to router='frozen' "
                         "and the step re-jits; requires a distilling "
                         "router — noisy_topk or gumbel — so params carry "
                         "w_frozen)")
    ap.add_argument("--ragged_bound", default="0",
                    help="ragged exchange: rows per peer shard (static "
                         "pad-to-max-per-peer width; 0 = local tokens * "
                         "top_k, which never drops; 'auto' = calibrate from "
                         "the load monitor's EMAs at every replan re-jit — "
                         "needs --replan_every)")
    ap.add_argument("--inter_bound", type=int, default=0,
                    help="hierarchical exchange: rows per slim inter-node "
                         "shard (0 = n_inner * ragged_bound, never drops at "
                         "the aggregation stage; only with a node mesh)")
    ap.add_argument("--ckpt_dir", default="",
                    help="checkpoint root: atomic verified checkpoints land "
                         "in step_<N>/ dirs (state after completing step N, "
                         "always in logical expert order regardless of the "
                         "live placement)")
    ap.add_argument("--save_every", type=int, default=0,
                    help="checkpoint every N completed steps (0 = only the "
                         "final save; needs --ckpt_dir)")
    ap.add_argument("--keep_ckpts", type=int, default=3,
                    help="retention: newest complete checkpoints kept by GC")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint under --ckpt_dir "
                         "that passes verification (corrupt ones are "
                         "skipped) and continue from its step; the data "
                         "stream fast-forwards so the trajectory matches an "
                         "uninterrupted run")
    ap.add_argument("--max_bad_steps", type=int, default=3,
                    help="step guard: tolerated consecutive non-finite "
                         "steps (each is skipped and retried from the last "
                         "good snapshot; exceeding aborts; 0 disables the "
                         "guard and its per-step host sync)")
    ap.add_argument("--snapshot_every", type=int, default=1,
                    help="guard snapshot cadence (1 = copy params/opt state "
                         "after every good step; higher amortizes the copy "
                         "at the cost of replaying more on restore)")
    ap.add_argument("--drop_spike", type=float, default=0.25,
                    help="guard: drop_frac above this for --drop_patience "
                         "consecutive steps forces the dropless ragged "
                         "bound (re-jit with ragged_bound=0)")
    ap.add_argument("--drop_patience", type=int, default=4)
    ap.add_argument("--metrics_out", default="",
                    help="write per-step telemetry records (JSONL): wall "
                         "time, device-side wire/drop/shadow counters, "
                         "HLO-modeled collective bytes, monitor snapshots, "
                         "replan events, and the resilience incident "
                         "timeline — faults, guard skips/restores, "
                         "checkpoint saves, resumes, rollbacks (repro.obs)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace (chrome://tracing / perfetto) "
                         "of host-side spans: train_step, replan, migrate")
    args = ap.parse_args()

    from repro.obs import JsonlSink, StepStats, modeled_collective_bytes
    from repro.obs import trace as obs_trace
    sink = JsonlSink(args.metrics_out) if args.metrics_out else None
    if args.trace:
        obs_trace.configure(enabled=True)
    # fault drills: REPRO_FAULTS='[{"point": "train_step", ...}]' arms the
    # registry for this process; every fired fault lands in the sink
    faults.arm_from_env()
    faults.set_sink(sink)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, num_layers=4, d_model=256)
    if args.dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=args.dispatch))
    if args.router and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=args.router))
    if args.freeze_router_at and (
            cfg.moe is None
            or cfg.moe.router not in ("noisy_topk", "gumbel")):
        raise SystemExit("--freeze_router_at needs a distilling router "
                         "(--router noisy_topk or gumbel) so params carry "
                         "w_frozen")
    opt = AdamW(lr=args.lr)

    opts = {"overlap_chunks": args.overlap_chunks,
            "wire_dtype": args.wire_dtype or None,
            "ragged_bound": ("auto" if args.ragged_bound == "auto"
                             else int(args.ragged_bound)),
            "inter_bound": args.inter_bound,
            "impl": args.impl}
    hook = None
    if args.mesh:
        dims = [int(v) for v in args.mesh.split("x")]
        if len(dims) == 3:  # DATAxNODExMODEL: hierarchical two-level mesh
            d, nn, m = dims
            mesh = make_local_mesh(d, m, node=nn)
        else:
            d, m = dims
            mesh = make_local_mesh(d, m)
        step_fn, pshard, oshard = jit_train_step(
            cfg, opt, mesh, args.batch, args.seq,
            num_microbatches=args.microbatches, opts=opts)
        params = jax.device_put(lm.init_params(jax.random.PRNGKey(0), cfg),
                                pshard)
        opt_state = jax.device_put(opt.init(params), oshard)
        if args.replan_every and cfg.moe is not None and m > 1:
            hook = ReplanHook(cfg, opt, mesh, args.batch, args.seq,
                              every=args.replan_every,
                              num_microbatches=args.microbatches, opts=opts,
                              per_layer=args.per_layer_plans, sink=sink)
            if not hook.enabled:  # no a2a path here: skip the per-step sync
                print("replan disabled: placement needs the a2a expert path")
                hook = None
            else:
                # ragged_bound=auto: the hook's monitor feeds the bound
                # calibration on every replan re-jit (opts dict is shared
                # with hook.opts, so observe() re-resolves through moe_dist)
                opts["load_monitor"] = hook.monitor
    else:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt,
                                          num_microbatches=args.microbatches,
                                          impl=args.impl))

    def modeled_of(fn, p, o, b, s):
        # HLO-derived collective bytes for the StepStats modeled-vs-measured
        # comparison; the AOT lowering shares nothing with fn's jit cache, so
        # only pay for it when telemetry asked for it
        try:
            return modeled_collective_bytes(
                fn.lower(p, o, b, jnp.int32(s)).compile())
        except Exception as e:  # missing column must be explainable, not mute
            print(f"warning: modeled collective bytes unavailable: {e}")
            obs_events.emit(sink, obs_events.MODELED_ERROR, step=int(s),
                            error=str(e))
            return {}

    # -- resilience: checkpointing + auto-resume + the step guard ----------
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, save_every=args.save_every,
                                    keep=args.keep_ckpts, sink=sink)
    start_step = 0
    if args.resume and manager is not None:
        # checkpoints are logical-order; the fresh run starts on the
        # identity placement, so no placement kwarg on the restore side
        res = manager.restore_latest({"params": params, "opt": opt_state})
        if res is not None:
            tree, last = res
            start_step = last + 1
            if args.mesh:
                params = jax.device_put(tree["params"], pshard)
                opt_state = jax.device_put(tree["opt"], oshard)
            else:
                params, opt_state = tree["params"], tree["opt"]
            print(f"resumed from step {last} "
                  f"({manager.step_dir(last)}); continuing at {start_step}")
        else:
            print(f"no restorable checkpoint under {args.ckpt_dir}; "
                  f"starting fresh")
    guard = None
    if args.max_bad_steps > 0:
        guard = StepGuard(max_bad_steps=args.max_bad_steps,
                          drop_threshold=args.drop_spike,
                          drop_patience=args.drop_patience,
                          snapshot_every=args.snapshot_every, sink=sink)

    telemetry = sink is not None or obs_trace.enabled()
    modeled: dict = {}
    data = SyntheticLM(cfg.vocab_size, args.seq)
    batch_iter = data.batches(args.batch)
    for _ in range(start_step):  # deterministic resume: replay the stream
        next(batch_iter)         # position an uninterrupted run would have
    t0 = time.time()
    step = start_step
    if guard is not None:  # seed snapshot: step 0 itself may go non-finite
        guard.commit(start_step - 1, params, opt_state)
    try:
        while step < args.steps:
            batch = {k: jnp.asarray(v) for k, v in next(batch_iter).items()}
            if (args.freeze_router_at and step >= args.freeze_router_at
                    and cfg.moe is not None and cfg.moe.router != "frozen"):
                # StableMoE stage 2: distillation is over — route through
                # w_frozen from here on.  Pure config flip + re-jit (params
                # already carry the distilled router); gate-id tables stop
                # changing, so later replans are pure load responses.
                cfg = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, router="frozen"))
                if args.mesh:
                    step_fn, pshard, oshard = jit_train_step(
                        cfg, opt, mesh, args.batch, args.seq,
                        num_microbatches=args.microbatches, opts=opts,
                        placement=hook.placement if hook is not None
                        else None)
                    params = jax.device_put(params, pshard)
                    opt_state = jax.device_put(opt_state, oshard)
                    if hook is not None:
                        hook.cfg = cfg  # replan re-jits keep the frozen gate
                else:
                    step_fn = jax.jit(make_train_step(
                        cfg, opt, num_microbatches=args.microbatches,
                        impl=args.impl))
                obs_events.emit(sink, obs_events.ROUTER_FROZEN, step=step)
                if sink is not None:
                    modeled = modeled_of(step_fn, params, opt_state, batch,
                                         step)
                print(f"step {step:5d} router frozen: gate-id tables are "
                      f"now stable")
            if step == start_step and sink is not None:
                modeled = modeled_of(step_fn, params, opt_state, batch, step)
            while True:  # retry loop, bounded by the guard's max_bad_steps
                ts = time.time()
                with obs_trace.span("train_step", step=step):
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch,
                                                         jnp.int32(step))
                    if telemetry:  # real wall times: don't run ahead
                        jax.block_until_ready(metrics)
                params, opt_state, metrics = faults.apply_step(
                    params, opt_state, metrics, step=step)
                if guard is None:
                    verdict = None
                    break
                loss = float(metrics["loss"])
                gnorm = float(metrics["grad_norm"])
                drop = float(metrics.get("drop_frac", 0.0))
                verdict = guard.check(step, loss=loss, grad_norm=gnorm,
                                      drop=drop)
                if verdict.ok:
                    break
                # non-finite step: the just-written state is poisoned —
                # reinstate the last good snapshot and retry this batch
                params, opt_state = guard.restore()
                if args.mesh:
                    params = jax.device_put(params, pshard)
                    opt_state = jax.device_put(opt_state, oshard)
                print(f"step {step:5d} non-finite ({verdict.reason}); "
                      f"restored step-{guard.snapshot_step} state, retrying")
            if verdict is not None and verdict.fallback_dropless:
                applied = False
                if args.mesh and opts.get("ragged_bound") not in (0, None):
                    opts["ragged_bound"] = 0  # provably dropless shards
                    mon = opts.get("load_monitor")
                    if mon is not None:  # keep auto mode from re-shrinking
                        mon.force_dropless = True
                    step_fn, pshard, oshard = jit_train_step(
                        cfg, opt, mesh, args.batch, args.seq,
                        num_microbatches=args.microbatches, opts=opts,
                        placement=hook.placement if hook is not None
                        else None)
                    applied = True
                    if sink is not None:
                        modeled = modeled_of(step_fn, params, opt_state,
                                             batch, step)
                obs_events.emit(sink, obs_events.DROP_FALLBACK, step=step,
                                applied=applied)
                print(f"step {step:5d} sustained drop spike: "
                      + ("forced dropless ragged bound" if applied else
                         "no bounded ragged exchange active (event only)"))
            if sink is not None:
                counters = {k: float(metrics[k])
                            for k in ("loss", "drop_frac", "wire_elems",
                                      "wire_bytes", "wire_bytes_intra",
                                      "wire_bytes_inter", "dropped",
                                      "shadow_hits", "imbalance")
                            if k in metrics}
                sink.emit(StepStats("train_step", step, time.time() - ts,
                                    counters=counters,
                                    modeled=modeled).record())
            new_fn = None
            if hook is not None:
                params, opt_state, new_fn = hook.observe(
                    step, metrics, params, opt_state,
                    loss=loss if guard is not None else None,
                    drop=drop if guard is not None else None)
                if new_fn is not None:
                    step_fn = new_fn
                    if sink is not None:  # new layout -> new profile
                        modeled = modeled_of(step_fn, params, opt_state,
                                             batch, step)
                    p = hook.placement
                    print(f"step {step:5d} replan: shadow={p.num_shadow} "
                          f"cap_scale={p.capacity_scale:.2f} "
                          f"imbalance={hook.monitor.imbalance:.2f}")
            if guard is not None:
                # post-observe so the snapshot is in the live physical
                # layout; force after a migration for the same reason
                guard.commit(step, params, opt_state,
                             force=new_fn is not None)
            if manager is not None:
                manager.maybe_save(
                    step, {"params": params, "opt": opt_state},
                    placement=hook.placement if hook is not None else None)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time() - t0:.1f}s)")
            step += 1
    except TrainingAborted as e:
        # persist the last good state so --resume can pick the run back up
        # (snapshot_step < start_step means only the seed exists — nothing
        # was accomplished, and labeling the init as a completed step would
        # skew a later resume's data fast-forward)
        if (manager is not None and guard is not None
                and guard.snapshot is not None
                and guard.snapshot_step >= start_step):
            p_good, o_good = guard.snapshot
            manager.save(guard.snapshot_step,
                         {"params": p_good, "opt": o_good},
                         placement=hook.placement if hook is not None
                         else None)
        print(f"aborted: {e}")
        if sink is not None:
            sink.close()
        raise SystemExit(1)
    if manager is not None and step > start_step:
        # final save so a completed run is always resumable/extendable
        manager.maybe_save(step - 1, {"params": params, "opt": opt_state},
                           placement=hook.placement if hook is not None
                           else None, force=True)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")
    if sink is not None:
        sink.close()
        print(f"metrics written to {args.metrics_out}")
    if args.trace:
        obs_trace.export(args.trace)
        print(f"trace written to {args.trace}")


if __name__ == "__main__":
    main()
