"""Typed serving API: the one interface the scheduler, the CLI and the
benchmarks all speak.

``ServeConfig`` carries every serving-loop knob (decode slots, paged-cache
block geometry, mesh, replan cadence); ``Request`` is what a client submits;
``Completion`` is what comes back, with the three timestamps every serving
SLO is written against (queued / first token / done) plus the full per-token
emission times so p50/p99 per-token latency falls out without extra plumbing.

``launch/serve.py main()`` builds a ServeConfig from its CLI flags
(``ServeConfig.from_args``) and ``launch/scheduler.ContinuousBatcher``
consumes it directly — flags and constructor kwargs are thin mappings onto
this one dataclass, not parallel configuration channels.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import List, Optional

import numpy as np


@dataclass
class ServeConfig:
    """Serving-loop configuration (model architecture rides separately as a
    ``repro.configs.base.ModelConfig``).

    slots          fixed decode-batch width: the number of in-flight
                   sequences one decode tick advances (vLLM-style continuous
                   batching admits/retires into these slots per step)
    max_len        per-request cap on prompt + generated tokens; sizes the
                   ring cache (non-paged) and the per-slot block table
    block_size     rows per KV-cache block (paged mode)
    num_blocks     physical blocks in the shared pool; 0 = auto
                   (slots * ceil(max_len / block_size) + the 2 reserved
                   null/scratch blocks — enough that admission never blocks
                   on pool space)
    paged          use the paged/blocked KV cache when the model family
                   supports it (plain attention caches; ssm/hybrid/audio
                   state caches fall back to the contiguous per-slot ring)
    policy         "continuous" (admit into any free slot every tick) or
                   "static" (admit only when every slot is free — the
                   head-of-line-blocking baseline fig11 measures against)
    mesh           "DxM" device mesh for the sharded decode step ("" = single
                   device)
    replan_every   decode ticks between placement-controller polls driven by
                   the online (L, E) decode-load feed; 0 disables serve-time
                   replanning
    per_layer_plans  plan per layer (PerLayerPlacement) on serve-time replans
    eos_id         optional early-stop token id
    arch / reduced model selection for the CLI path (ignored when the caller
                   already has params + ModelConfig in hand)
    metrics_out / trace   telemetry outputs (repro.obs), same semantics as
                   train.py's flags
    """

    slots: int = 8
    max_len: int = 256
    block_size: int = 16
    num_blocks: int = 0
    paged: bool = True
    policy: str = "continuous"
    mesh: str = ""
    replan_every: int = 0
    per_layer_plans: bool = True
    eos_id: Optional[int] = None
    arch: str = "smollm-360m"
    reduced: bool = False
    metrics_out: str = ""
    trace: str = ""

    def __post_init__(self):
        if self.policy not in ("continuous", "static"):
            raise ValueError(f"unknown serving policy {self.policy!r}")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.max_len < 1:
            raise ValueError("max_len must be >= 1")

    @property
    def blocks_per_slot(self) -> int:
        """Block-table width: logical blocks covering max_len positions."""
        return -(-self.max_len // self.block_size)

    @property
    def pool_blocks(self) -> int:
        """Physical pool size (auto-sized unless num_blocks is explicit).
        Blocks 0 (null: read target of unallocated table entries) and 1
        (scratch: write target of inactive slots) are reserved."""
        if self.num_blocks:
            return self.num_blocks
        return self.slots * self.blocks_per_slot + 2

    def mesh_shape(self) -> Optional[tuple]:
        """Parsed (data, model) mesh dims, or None for single-device."""
        if not self.mesh:
            return None
        d, m = (int(v) for v in self.mesh.split("x"))
        return d, m

    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        """Thin argparse.Namespace -> ServeConfig mapping: any attribute
        matching a field name is taken, everything else keeps its default.
        ``--batch`` (the historical flag for the decode width) maps to
        ``slots`` when no explicit ``--slots`` was given."""
        kw = {}
        names = {f.name for f in fields(cls)}
        for name in names:
            if getattr(args, name, None) is not None and hasattr(args, name):
                kw[name] = getattr(args, name)
        if "slots" not in kw and getattr(args, "batch", None) is not None:
            kw["slots"] = args.batch
        return cls(**kw)


@dataclass
class Request:
    """One generation request.  ``arrival`` is the client-side submission
    timestamp (time.time()); None means "stamp at submit"."""

    id: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    arrival: Optional[float] = None


@dataclass
class Completion:
    """A finished request: generated tokens plus the serving timeline.

    queued        when the request entered the queue (Request.arrival)
    first_token   when the first generated token was emitted (prefill done)
    done          when the last token was emitted
    token_times   emission timestamp of every generated token — consecutive
                  deltas are the per-token latencies fig11's p50/p99 report
    """

    request_id: int
    tokens: List[int] = field(default_factory=list)
    prompt_len: int = 0
    queued: float = 0.0
    first_token: float = 0.0
    done: float = 0.0
    token_times: List[float] = field(default_factory=list)

    @property
    def ttft(self) -> float:
        """Time to first token (queue wait + prefill)."""
        return self.first_token - self.queued

    @property
    def latencies(self) -> List[float]:
        """Per-token latencies: first token pays the queue+prefill, the rest
        are decode-tick deltas (including any stalls)."""
        if not self.token_times:
            return []
        out = [self.token_times[0] - self.queued]
        out.extend(b - a for a, b in zip(self.token_times, self.token_times[1:]))
        return out
