"""Continuous batching scheduler (beyond-paper serving subsystem).

A fixed-size decode batch whose slots are independently occupied by
requests: new prompts prefill into a free slot (single-sequence prefill
inserted into the batched cache), every decode step advances all active
slots with PER-SEQUENCE positions, finished sequences free their slot
immediately for the next queued request — no head-of-line blocking on the
longest sequence (the vLLM-style serving pattern, sized down).

Host-side orchestration; the device work is one jitted batched decode_step
per tick regardless of occupancy.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 cache_len: int = 256, eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.B = max_batch
        self.W = cache_len
        self.eos_id = eos_id
        self.cache = lm.init_cache(cfg, max_batch, cache_len)
        self.pos = np.zeros(max_batch, np.int32)  # next position per slot
        self.slot_req: list = [None] * max_batch
        self.queue: list = []
        self.next_tok = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(functools.partial(lm.decode_step, cfg=cfg))
        self._prefill = jax.jit(functools.partial(lm.prefill, cfg=cfg))
        self._empty_slot_cache = lm.init_cache(cfg, 1, cache_len)

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]  # (1, S)
            logits, c1, _ = self._prefill(self.params, tokens=prompt,
                                          cache=self._empty_slot_cache)
            # insert the single-sequence cache into batch slot `slot`
            self.cache = jax.tree.map(
                lambda big, one: big.at[:, slot].set(one[:, 0]),
                self.cache, c1)
            self.slot_req[slot] = req
            self.pos[slot] = req.prompt.shape[0]
            self.next_tok[slot] = int(jnp.argmax(logits[0, -1]))
            req.out.append(int(self.next_tok[slot]))

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        self.slot_req[slot] = None
        # reset the slot's cache so stale entries never leak into a new request
        self.cache = jax.tree.map(
            lambda big, one: big.at[:, slot].set(one[:, 0]),
            self.cache, self._empty_slot_cache)
        self.pos[slot] = 0

    # -- one decode tick -----------------------------------------------------

    def step(self) -> int:
        """Admit queued requests, decode one token for every active slot.
        Returns the number of active slots this tick."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.next_tok, jnp.int32)[:, None]  # (B, 1)
        pos = jnp.asarray(self.pos, jnp.int32)  # per-sequence positions
        logits, self.cache, _ = self._decode(self.params, tokens=toks,
                                             pos=pos, cache=self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            self.pos[slot] += 1
            tok = int(nxt[slot])
            req.out.append(tok)
            self.next_tok[slot] = tok
            if (len(req.out) >= req.max_new
                    or (self.eos_id is not None and tok == self.eos_id)):
                self._retire(slot)
        return len(active)

    def run(self, max_ticks: int = 10000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                return
            self.step()
        raise RuntimeError("scheduler did not drain")
