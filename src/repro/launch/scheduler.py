"""Continuous batching engine (beyond-paper serving subsystem).

A fixed-size decode batch whose slots are independently occupied by
requests: new prompts prefill into a free slot every tick, every decode
step advances all active slots with PER-SEQUENCE positions, finished
sequences free their slot immediately for the next queued request — no
head-of-line blocking on the longest sequence (the vLLM-style serving
pattern, sized down).  Host-side orchestration; the device work is one
jitted batched decode step per tick regardless of occupancy.

Three pieces beyond the original slot loop:

* **Paged KV cache** — slots read/write a shared block pool
  (models/lm.init_paged_cache) through per-slot block tables instead of a
  contiguous (B, W) ring.  A BlockAllocator free-lists the physical
  blocks; admission reserves the request's full ceil((S+max_new)/bs)
  blocks up front, so a decode step can never run out of cache mid-flight
  (lazy growth is a ROADMAP follow-on).  Decode through the table view is
  bitwise identical to the ring (tests/test_scheduler): the gathered view
  index equals the absolute position when blocks are table-ordered, and
  masked entries contribute exact zeros.

* **Online replan** — ServeReplanHook mirrors launch.train.ReplanHook on
  the serving side: the decode step's (L, E) expert-load feed
  (make_serve_step(layer_loads=True)) drives a LoadMonitor EMA, a
  PlacementController polls it every ``replan_every`` ticks, and accepted
  plans migrate live params + re-jit between ticks under PR-8 probation
  (drop-frac judged; regressing plans roll back and are blacklisted).
  Safe mid-traffic because the decode dist is pinned to the psum mode
  (serve.decode_dist), which is bitwise layout-invariant — a replan is
  invisible in the token stream.

* **Admission policy** — "continuous" admits into any free slot each
  tick; "static" admits only when every slot is free, which reproduces
  the static-batch baseline's head-of-line blocking on the identical
  decode path (the fig11 comparison).
"""
from __future__ import annotations

import functools
import time
import warnings
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import lm
from repro.launch.serve_api import Completion, Request as _Request, ServeConfig


def __getattr__(name):
    if name == "Request":
        warnings.warn(
            "repro.launch.scheduler.Request moved to "
            "repro.launch.serve_api.Request; import it from there "
            "(this re-export will be removed)", DeprecationWarning,
            stacklevel=2)
        return _Request
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class BlockAllocator:
    """Free-list over the pool's non-reserved physical blocks.

    Rows 0 (null) and 1 (scratch) are reserved by the paged cache layout
    (models/attention.RESERVED_BLOCKS); everything above is handed out in
    whole-request batches and returned on retire.  Pure host state — the
    device only ever sees the resulting block tables.
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= A.RESERVED_BLOCKS:
            raise ValueError(
                f"pool needs more than the {A.RESERVED_BLOCKS} reserved "
                f"blocks, got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(A.RESERVED_BLOCKS, num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n physical block ids, or None when the pool can't cover them
        (admission then blocks FIFO — no skip-ahead, no partial grants)."""
        if n > len(self._free):
            return None
        out = self._free[:n]
        del self._free[:n]
        return out

    def free(self, blocks: List[int]) -> None:
        self._free.extend(blocks)


def _insert_body(pool, ring, blocks):
    """Scatter a single-sequence prefill ring (L, 1, nb*bs, ...) into pool
    rows ``blocks``.  Ring tail entries beyond the prompt carry the fresh
    init state (zeros, positions -1), which matches a clean pool block, so
    partial tail blocks are safe to insert whole."""
    def ins(pl, rl):
        L, bs = pl.shape[0], pl.shape[2]
        nb = blocks.shape[0]
        r = rl[:, 0].reshape(L, nb, bs, *rl.shape[3:])
        return pl.at[:, blocks].set(r.astype(pl.dtype))

    new = [ins(p, r) for p, r in zip(jax.tree.leaves(pool),
                                     jax.tree.leaves(ring))]
    return jax.tree.unflatten(jax.tree.structure(pool), new)


def _release_body(pool, blocks):
    """Reset freed blocks' positions to -1 so later reads mask them.  The
    stale k/v payload may remain: masked scores are exactly ``_NEG`` so
    their softmax weight is 0.0 and the contribution cancels bitwise."""
    return pool._replace(positions=pool.positions.at[:, blocks].set(-1))


_insert_blocks = functools.partial(jax.jit, donate_argnums=(0,))(_insert_body)
_release_blocks = functools.partial(jax.jit,
                                    donate_argnums=(0,))(_release_body)


@dataclass
class _Slot:
    """Host-side state of one occupied decode slot."""

    req: _Request
    blocks: Optional[List[int]]  # physical block ids (paged mode only)
    out: List[int] = field(default_factory=list)
    times: List[float] = field(default_factory=list)


class ServeReplanHook:
    """Serve-side mirror of launch.train.ReplanHook: decode-load EMA ->
    PlacementController -> live migrate + re-jit, under drop-frac probation
    (there is no loss at serve time).  Owned by ContinuousBatcher; one
    ``observe`` call per decode tick."""

    def __init__(self, batcher: "ContinuousBatcher", num_ranks: int, *,
                 every: int, per_layer: bool = True, sink=None):
        from repro.core.dispatch import expert_capacity
        from repro.core.monitor import LoadMonitor
        from repro.placement import PlacementController, load_calibration
        from repro.resilience import ReplanProbation

        cfg = batcher.cfg
        moe = cfg.moe
        L = cfg.num_layers if per_layer else 0
        self.batcher = batcher
        self.per_layer = per_layer
        self.sink = sink
        self.monitor = LoadMonitor(moe.num_experts, ema=0.9, num_layers=L)
        self.controller = PlacementController(
            self.monitor, num_ranks, d_model=cfg.d_model,
            d_hidden=moe.d_expert_hidden,
            capacity=expert_capacity(batcher.B, moe.num_experts, moe.top_k,
                                     moe.capacity_factor),
            capacity_factor=moe.capacity_factor, every=every, train=False,
            num_layers=L, constants=load_calibration())
        self.probation = ReplanProbation(
            window=max(4, min(64, every // 4)), sink=sink)
        # decode ticks are cheap; sample the device load EMA sparsely like
        # the train hook so the host never serializes on a per-tick fetch
        self.sync_every = max(1, every // 16)
        self._drop_ema: Optional[float] = None

    def observe(self, tick: int, md: dict) -> None:
        from repro.core.balance import MoEMetrics

        drop = float(md["drop_frac"]) if "drop_frac" in md else None
        if drop is not None:
            self._drop_ema = (drop if self._drop_ema is None
                              else 0.9 * self._drop_ema + 0.1 * drop)
        load_key = "load_layers" if self.per_layer else "load"
        if load_key in md and tick % self.sync_every == 0:
            self.monitor.update(MoEMetrics(
                0.0, 0.0, jax.device_get(md[load_key]),
                drop if drop is not None else 0.0))
        if self.probation.active:
            decision = self.probation.observe(tick, drop=drop)
            if decision.rollback:
                self.batcher.apply_placement(decision.old_plan)
                self.controller.rollback(decision.old_plan,
                                         decision.new_plan)
                return
            if self.probation.active:  # still judging: defer new replans
                return
        old = self.controller.current
        new = self.controller.maybe_replan(tick)
        if new is None:
            return
        self.batcher.apply_placement(new)
        # a serve-time replan must not *introduce* drops even if none were
        # measured before it
        self.probation.start(tick, old, new, baseline_drop=(
            self._drop_ema if self._drop_ema is not None else 0.0))
        if self.sink is not None:
            self.sink.emit({"kind": "replan", "step": tick,
                            "imbalance": self.monitor.imbalance})


class ContinuousBatcher:
    """The continuous-batching serve loop behind ``serve.py --continuous``.

    Construct from a :class:`~repro.launch.serve_api.ServeConfig` (the
    legacy ``max_batch``/``cache_len``/``eos_id`` kwargs still work and
    build one).  ``params`` must already be in ``placement``'s physical
    order when a plan is passed (placement.from_logical) — the same
    contract as serve.jit_serve_step.

    Public surface: ``submit(Request)``, ``step()``, ``run()``,
    ``apply_placement(plan)``, plus ``completions`` / ``ticks`` /
    ``replans`` for the driver.
    """

    def __init__(self, params, cfg: ModelConfig,
                 serve_cfg: Optional[ServeConfig] = None, *, mesh=None,
                 placement=None, sink=None, opts: Optional[dict] = None,
                 max_batch: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 eos_id: Optional[int] = None):
        if serve_cfg is None:
            serve_cfg = ServeConfig(slots=max_batch or 8,
                                    max_len=cache_len or 256, eos_id=eos_id)
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.B = serve_cfg.slots
        self.eos_id = serve_cfg.eos_id
        self.paged = serve_cfg.paged and lm.supports_paged(cfg)
        self.sink = sink
        self.plan = placement
        self._opts = dict(opts or {})
        if mesh is None and serve_cfg.mesh:
            from repro.launch.mesh import make_local_mesh
            d, m = serve_cfg.mesh_shape()
            mesh = make_local_mesh(d, m)
        self.mesh = mesh

        # slot + cache state
        self.pos = np.zeros(self.B, np.int32)  # next write position per slot
        self.next_tok = np.zeros(self.B, np.int32)
        self.slots: List[Optional[_Slot]] = [None] * self.B
        self.queue: List[_Request] = []
        self.completions: List[Completion] = []
        self.ticks = 0
        self.replans = 0
        if self.paged:
            self.bs = serve_cfg.block_size
            self.nb = serve_cfg.blocks_per_slot
            self.pool = lm.init_paged_cache(cfg, serve_cfg.pool_blocks,
                                            self.bs)
            self.tables = np.zeros((self.B, self.nb), np.int32)  # NULL_BLOCK
            self.allocator = BlockAllocator(serve_cfg.pool_blocks)
            self._insert, self._release = _insert_blocks, _release_blocks
            if self.mesh is not None:
                # pin the host-side pool edits (prefill insert, retire
                # release) to the decode step's pool sharding — the decode
                # jit donates the pool, and a donated arg must arrive
                # committed to the declared in_sharding
                from repro.launch.sharding import cache_specs
                pool_shape = jax.eval_shape(functools.partial(
                    lm.init_paged_cache, cfg, serve_cfg.pool_blocks,
                    self.bs))
                cshard = jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(self.mesh, s),
                    cache_specs(pool_shape, self.mesh, self.B, paged=True),
                    is_leaf=lambda s: isinstance(
                        s, jax.sharding.PartitionSpec))
                rep = jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec())
                self.pool = jax.device_put(self.pool, cshard)
                self._insert = jax.jit(
                    _insert_body, in_shardings=(cshard, rep, rep),
                    out_shardings=cshard, donate_argnums=(0,))
                self._release = jax.jit(
                    _release_body, in_shardings=(cshard, rep),
                    out_shardings=cshard, donate_argnums=(0,))
        else:
            self.W = serve_cfg.max_len
            self.cache = lm.init_cache(cfg, self.B, self.W)
            self._empty_slot_cache = lm.init_cache(cfg, 1, self.W)

        # online replanning (serve-time load-balance loop)
        self._replan: Optional[ServeReplanHook] = None
        if serve_cfg.replan_every > 0 and cfg.moe is not None:
            ranks = self._expert_ranks()
            self._replan = ServeReplanHook(
                self, ranks, every=serve_cfg.replan_every,
                per_layer=serve_cfg.per_layer_plans, sink=sink)
            if self.plan is None:
                # engage the placement path from tick 0 (identity plan =
                # logical order) so every later plan switch stays on the
                # layout-invariant placed decode
                self.plan = self._replan.controller.current
        self._want_metrics = sink is not None or self._replan is not None
        self._want_loads = self._replan is not None
        self._build_steps()

    # -- jitted device steps -------------------------------------------------

    def _expert_ranks(self) -> int:
        if self.mesh is None:
            return 1
        from repro.launch import serve
        d = serve.decode_dist(self.cfg, self.mesh, self.B, opts=self._opts)
        return d.expert_parallelism if d is not None and d.expert_axes else 1

    def _build_steps(self) -> None:
        """(Re-)jit the decode and prefill steps for the current placement.
        Placement tables bake into the jaxpr as constants, so every plan
        switch rebuilds both."""
        from repro.core import fmoe
        from repro.launch import serve

        cfg = self.cfg
        if self.mesh is not None:
            opts = dict(self._opts)
            if self.plan is not None:
                opts["placement"] = self.plan
            if self.paged:
                self._decode, _ = serve.jit_paged_serve_step(
                    cfg, self.mesh, self.B, self.scfg.pool_blocks, self.bs,
                    opts=opts, with_metrics=self._want_metrics,
                    layer_loads=self._want_loads)
            else:
                dist = serve.decode_dist(cfg, self.mesh, self.B, opts=opts)
                self._decode = jax.jit(serve.make_serve_step(
                    cfg, dist=dist, with_metrics=self._want_metrics,
                    layer_loads=self._want_loads), donate_argnums=(3,))
            # prefill is single-sequence: token_axes drop to () (1 token row
            # can't shard over data), psum-pinned like decode so the same
            # placement applies on both phases of a request
            pdist = serve.decode_dist(cfg, self.mesh, 1, opts=opts)
        else:
            pdist = (fmoe.DistConfig.local(placement=self.plan)
                     if self.plan is not None else None)
            self._decode = jax.jit(serve.make_serve_step(
                cfg, dist=pdist, with_metrics=self._want_metrics,
                paged=self.paged, layer_loads=self._want_loads),
                donate_argnums=(3,))
        self._prefill = jax.jit(
            functools.partial(lm.prefill, cfg=cfg, dist=pdist))

    def apply_placement(self, plan) -> None:
        """Switch the live expert layout mid-traffic: permute params from
        the current plan's physical order into ``plan``'s and re-jit the
        serve steps.  Decode runs the psum expert mode (serve.decode_dist),
        which combines per-slot before the fixed-order k-sum, so the tokens
        decoded after the switch are bitwise identical to never switching
        (tests/test_scheduler differential test)."""
        from repro.placement import from_logical, migrate

        if self.plan is not None:
            self.params = migrate(self.params, self.plan, plan)
        else:
            self.params = from_logical(self.params, plan)
        self.plan = plan
        self._build_steps()
        self.replans += 1

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: _Request) -> None:
        total = int(req.prompt.shape[0]) + req.max_new_tokens
        cap = self.scfg.max_len if self.paged else self.W
        if total > cap:
            raise ValueError(
                f"request {req.id}: prompt+max_new_tokens = {total} exceeds "
                f"max_len = {cap}")
        if req.arrival is None:
            req.arrival = time.time()
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self) -> None:
        free = self._free_slots()
        if self.scfg.policy == "static" and len(free) < self.B:
            return  # static baseline: admit only at whole-batch boundaries
        for slot in free:
            if not self.queue:
                break
            req = self.queue[0]
            S = int(req.prompt.shape[0])
            blocks = None
            if self.paged:
                need = -(-(S + req.max_new_tokens) // self.bs)
                blocks = self.allocator.alloc(need)
                if blocks is None:
                    break  # FIFO under pool pressure: no skip-ahead
            self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            if self.paged:
                # prefill a temp ring rounded up to whole blocks, then
                # block-scatter it into the request's pool rows
                nb_p = -(-S // self.bs)
                ring = lm.init_cache(self.cfg, 1, nb_p * self.bs)
                logits, c1, _ = self._prefill(self.params, tokens=prompt,
                                              cache=ring)
                self.pool = self._insert(
                    self.pool, c1, jnp.asarray(blocks[:nb_p], jnp.int32))
                self.tables[slot, :len(blocks)] = blocks
                self.tables[slot, len(blocks):] = A.NULL_BLOCK
            else:
                logits, c1, _ = self._prefill(self.params, tokens=prompt,
                                              cache=self._empty_slot_cache)
                self.cache = jax.tree.map(
                    lambda big, one: big.at[:, slot].set(one[:, 0]),
                    self.cache, c1)
            now = time.time()
            tok = int(jnp.argmax(logits[0, -1]))
            self.slots[slot] = _Slot(req=req, blocks=blocks, out=[tok],
                                     times=[now])
            self.pos[slot] = S
            self.next_tok[slot] = tok
            if self.sink is not None:
                self.sink.emit({"kind": "serve_admit", "tick": self.ticks,
                                "id": req.id, "slot": slot,
                                "queue_wait": now - req.arrival})

    def _retire(self, slot: int, now: float) -> None:
        st = self.slots[slot]
        self.completions.append(Completion(
            request_id=st.req.id, tokens=st.out,
            prompt_len=int(st.req.prompt.shape[0]), queued=st.req.arrival,
            first_token=st.times[0], done=now, token_times=st.times))
        if self.paged:
            self.pool = self._release(
                self.pool, jnp.asarray(st.blocks, jnp.int32))
            self.allocator.free(st.blocks)
            self.tables[slot, :] = A.NULL_BLOCK
        else:
            # reset the slot's ring so stale entries never leak forward
            self.cache = jax.tree.map(
                lambda big, one: big.at[:, slot].set(one[:, 0]),
                self.cache, self._empty_slot_cache)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.next_tok[slot] = 0
        if self.sink is not None:
            self.sink.emit({"kind": "serve_retire", "tick": self.ticks,
                            "id": st.req.id, "slot": slot,
                            "tokens": len(st.out)})

    # -- one decode tick -----------------------------------------------------

    def step(self) -> int:
        """Admit queued requests, decode one token for every active slot.
        Returns the number of active slots this tick."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.next_tok, jnp.int32)[:, None]  # (B, 1)
        pos = jnp.asarray(self.pos, jnp.int32)  # per-sequence positions
        if self.paged:
            logits, self.pool, md = self._decode(
                self.params, toks, pos, self.pool,
                jnp.asarray(self.tables, jnp.int32))
        else:
            logits, self.cache, md = self._decode(self.params, toks, pos,
                                                  self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        now = time.time()
        for slot in active:
            st = self.slots[slot]
            self.pos[slot] += 1
            tok = int(nxt[slot])
            st.out.append(tok)
            st.times.append(now)
            self.next_tok[slot] = tok
            if (len(st.out) >= st.req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                self._retire(slot, now)
        self.ticks += 1
        if self._replan is not None:
            self._replan.observe(self.ticks, md)
        return len(active)

    def run(self, max_ticks: int = 10000) -> None:
        """Drain the queue: tick until every submitted request completed."""
        for _ in range(max_ticks):
            if not self.queue and all(s is None for s in self.slots):
                return
            if self.step() == 0 and self.queue:
                raise RuntimeError(
                    "admission stalled: the shared pool cannot cover the "
                    "next queued request (raise ServeConfig.num_blocks or "
                    "max_len/block_size geometry)")
        raise RuntimeError("scheduler did not drain")
