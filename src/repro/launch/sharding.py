"""Logical-axis sharding rules (MaxText-style) realizing the paper's §3.2
gradient-sync tag semantics (see repro.core.sync and DESIGN.md §2/§5).

Every parameter path maps to logical axes via the first matching rule; the
logical->mesh table turns them into PartitionSpecs, with a divisibility guard
that falls back to replication when a dim doesn't split evenly.

Tag realization: router/norms match no sharded rule -> fully replicated
("world"); TP projections shard over "model" ("dp"); expert tensors shard
their expert dim over "model" ("none").
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import data_axes

# (path regex, logical axes per dim) — first match wins.  Paths are
# '/'-joined; stacked layer params keep their in-layer path (the leading L
# dim gets None prepended automatically).
RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("vocab", "embed")),
    (r"lm_head/w$", ("embed", "vocab")),
    # router ("world" tag): replicated everywhere
    (r"router/w$", (None, None)),
    # experts ("none" tag): expert dim over the expert axis, hidden dim over
    # the data axis (FSDP bytes identical to d-sharding, but the layout
    # coincides with expert-internal TP so enabling it needs no resharding)
    (r"experts/wi(_gate|_up)?$", ("expert", None, "embed")),
    (r"experts/wo$", ("expert", "embed", None)),
    # attention (tag "dp"): heads over model
    (r"attn/w[qkv]/w$", ("embed", "heads")),
    (r"attn/w[qkv]/b$", ("heads",)),
    (r"attn/wo/w$", ("heads", "embed")),
    # MLA
    (r"attn/w_dq/w$", ("embed", None)),
    (r"attn/w_uq/w$", (None, "heads")),
    (r"attn/w_dkv/w$", ("embed", None)),
    (r"attn/w_kr/w$", ("embed", None)),
    (r"attn/w_u[kv]$", ("heads", None, None)),
    # cross attention (whisper decoder)
    (r"cross_attn/w[qkv]/w$", ("embed", "heads")),
    (r"cross_attn/wo/w$", ("heads", "embed")),
    # dense FFN / shared experts / dense residual
    (r"(ffn|shared|dense)/wi(_gate|_up)?/?w?$", ("embed", "ffn")),
    (r"(ffn|shared|dense)/wo/?w?$", ("ffn", "embed")),
    # rwkv6 time-mix
    (r"rwkv/w[rkvg]/w$", ("embed", "heads")),
    (r"rwkv/wo/w$", ("heads", "embed")),
    (r"rwkv/ts_w1$", ("embed", None)),
    (r"rwkv/ts_w2$", (None, None, "embed")),
    (r"rwkv/decay_w1$", ("embed", None)),
    (r"rwkv/decay_w2$", (None, "embed")),
    (r"rwkv/cm_k/w$", ("embed", "ffn")),
    (r"rwkv/cm_v/w$", ("ffn", "embed")),
    (r"rwkv/cm_r/w$", ("embed", "heads")),
    # mamba (hymba)
    (r"mamba/in_proj/w$", ("embed", "ffn")),
    (r"mamba/out_proj/w$", ("ffn", "embed")),
    (r"mamba/conv_w$", (None, "ffn")),
    (r"mamba/conv_b$", ("ffn",)),
    (r"mamba/x_proj/w$", ("ffn", None)),
    (r"mamba/dt_proj/w$", (None, "ffn")),
    (r"mamba/dt_proj/b$", ("ffn",)),
    (r"mamba/A_log$", ("ffn", None)),
    (r"mamba/D$", ("ffn",)),
]

LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    "embed": ("data",),  # FSDP
    "heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),  # the paper's expert parallelism
    "vocab": ("model",),
}

# Serving keeps weights TP-resident: no optimizer states at inference, so the
# bf16 weights fit without FSDP and the per-layer weight all-gathers vanish
# (§Perf, decode hillclimb).
LOGICAL_TO_MESH_SERVE = dict(LOGICAL_TO_MESH, embed=())

# §Perf multi-pod: experts sharded over (pod, model) instead of model —
# removes the cross-pod expert-gradient all-reduce that makes multi-pod MoE
# training collective-bound (MoE carries ~E/k x params per active FLOP, so
# replicating experts across pods is disproportionately expensive).
# Overridable cell so the paper-faithful baseline stays the default.
EXPERT_AXES: list = [("model",)]


# §Perf multi-pod: force-replicate MLA up-projections over the model axis.
# SPMD hits an involuntary full-batch replication (21.7 GB f32 AR/layer on
# deepseek 2x16x16) when MLA heads are model-sharded with batch over
# (pod, data); replication costs only the FSDP gathers.
MLA_REPLICATE: list = [False]


def _cell_override(cell: list, value):
    import contextlib

    @contextlib.contextmanager
    def _cm():
        old = cell[0]
        cell[0] = value
        try:
            yield
        finally:
            cell[0] = old
    return _cm()


def expert_axes_override(axes: tuple):
    return _cell_override(EXPERT_AXES, axes)


def option_overrides(opts: dict, mesh):
    """ExitStack applying every §Perf sharding override requested in opts."""
    import contextlib
    stack = contextlib.ExitStack()
    opts = opts or {}
    if opts.get("expert_pod") and "pod" in getattr(mesh, "axis_names", ()):
        stack.enter_context(expert_axes_override(("pod", "model")))
    if "node" in getattr(mesh, "axis_names", ()):
        # hierarchical mesh: the expert dim spans (node, model), node-major —
        # the rank order DistConfig.node_axis's two-level exchange assumes
        stack.enter_context(expert_axes_override(("node", "model")))
    if opts.get("mla_replicate"):
        stack.enter_context(_cell_override(MLA_REPLICATE, True))
    return stack


def _mesh_axes_for(logical, mesh, table=None) -> Any:
    if logical is None:
        return None
    table = table or LOGICAL_TO_MESH
    src = EXPERT_AXES[0] if logical == "expert" else table[logical]
    axes = tuple(a for a in src if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _axis_size(entry, mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        out = 1
        for a in entry:
            out *= mesh.shape[a]
        return out
    return mesh.shape[entry]


def rules_for(cfg, mesh) -> list:
    """RULES, prefixed with arch-aware attention overrides.

    Sharding a flat (d, H*hd) projection over the model axis implicitly
    splits *heads*; when H (or KV) doesn't divide the axis, SPMD cannot keep
    the per-head layout through the (B,S,H,hd) reshape and falls back to
    replicating whole attention activations (a ~30 GB f32 all-reduce per
    layer on arctic's H=56).  Replicating the offending projections over
    model instead costs only the FSDP gather and keeps everything local.
    """
    if cfg is None or getattr(cfg, "attention", None) is None:
        return RULES
    mp = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else 1
    a = cfg.attention
    extra = []
    if a.kind == "gqa" and a.num_kv_heads % mp:
        extra += [(r"(cross_)?attn/w[kv]/w$", ("embed", None)),
                  (r"(cross_)?attn/w[kv]/b$", (None,))]
    if a.kind == "gqa" and a.num_heads % mp:
        extra += [(r"(cross_)?attn/wq/w$", ("embed", None)),
                  (r"(cross_)?attn/wq/b$", (None,)),
                  (r"(cross_)?attn/wo/w$", (None, "embed"))]
    if a.kind == "mla" and (a.num_heads % mp or MLA_REPLICATE[0]):
        extra += [(r"attn/w_u[kq]", ("embed", None)),
                  (r"attn/w_uv$", (None, None, None)),
                  (r"attn/wo/w$", (None, "embed"))]
    return extra + RULES


def spec_for(path: str, shape: tuple, mesh, *, stacked: bool,
             mode: str = "train", rules: list | None = None) -> P:
    table = LOGICAL_TO_MESH_SERVE if mode == "serve" else LOGICAL_TO_MESH
    for pattern, logical in (rules or RULES):
        if re.search(pattern, path):
            dims = [_mesh_axes_for(l, mesh, table) for l in logical]
            break
    else:
        dims = [None] * (len(shape) - (1 if stacked else 0))
    if stacked:
        dims = [None] + dims
    dims = dims[:len(shape)]
    dims += [None] * (len(shape) - len(dims))
    # divisibility guard: replicate any dim that doesn't split evenly
    dims = [d if shape[i] % _axis_size(d, mesh) == 0 else None
            for i, d in enumerate(dims)]
    return P(*dims)


def _flat_paths(tree, prefix=""):
    if isinstance(tree, P):  # old-jax PartitionSpec subclasses tuple: a leaf
        yield prefix[:-1], tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flat_paths(v, f"{prefix}{k}/")
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            yield from _flat_paths(getattr(tree, k), f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flat_paths(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree


def tree_specs(tree, mesh, mode: str = "train", cfg=None) -> Any:
    """PartitionSpec pytree mirroring ``tree`` (abstract or concrete)."""
    flat = dict(_flat_paths(tree))
    rules = rules_for(cfg, mesh) if cfg is not None else None
    specs = {p: spec_for(p, v.shape, mesh, mode=mode, rules=rules,
                         stacked=p.startswith(("layers/", "enc_layers/")))
             for p, v in flat.items()}
    return _rebuild(tree, specs, "")


def _rebuild(like, specs, prefix):
    if isinstance(like, dict):
        return {k: _rebuild(v, specs, f"{prefix}{k}/") for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(*(_rebuild(getattr(like, k), specs, f"{prefix}{k}/")
                            for k in like._fields))
    if isinstance(like, (list, tuple)):
        return type(like)(_rebuild(v, specs, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    return specs[prefix[:-1]]


def tree_shardings(tree, mesh, mode: str = "train", cfg=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(tree, mesh, mode, cfg),
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Activation / input specs
# ---------------------------------------------------------------------------


def batch_spec(batch_size: int, mesh, extra_dims: int = 1) -> P:
    """Shard the batch dim over (pod, data) where divisible."""
    axes = data_axes(mesh)
    if not axes or batch_size % _axis_size(axes if len(axes) > 1 else axes[0], mesh):
        axes = None
    elif len(axes) == 1:
        axes = axes[0]
    return P(axes, *([None] * extra_dims))


def cache_specs(cache_tree, mesh, batch_size: int,
                seq_shard: bool = False, paged: bool = False) -> Any:
    """Decode-cache specs: batch over data axes; the big dim over model.

    Default: trailing feature dim (head_dim / latent) over model.
    ``seq_shard``: the ring/window dim over model instead — decode attention
    then reduces over the sharded window via small psums rather than
    all-gathering the cache every layer (§Perf, decode hillclimb).

    ``paged``: the tree is a paged block pool (lm.init_paged_cache — leaves
    (L, P, bs, ...), no batch dim).  Blocks are shared across decode slots,
    so the pool replicates over the data axes and only the trailing feature
    dim (head_dim / latent) shards over model; block tables stay host-side.
    """
    bs = batch_spec(batch_size, mesh, 0)[0]
    mp = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def pool_spec(path, leaf):
        ndim = len(leaf.shape)
        dims = [None] * ndim
        final = path.split("/")[-1]
        if (final in ("k", "v", "ckv", "kr") and ndim >= 4 and mp > 1
                and leaf.shape[-1] % mp == 0):
            dims[-1] = "model"
        return P(*dims)

    def leaf_spec(path, leaf):
        if paged:
            return pool_spec(path, leaf)
        ndim = len(leaf.shape)
        dims = [None] * ndim
        # batch dim: index 1 for stacked (L, B, ...) leaves, 0 otherwise
        if ndim >= 2 and leaf.shape[1] == batch_size:
            b_idx = 1
        elif leaf.shape and leaf.shape[0] == batch_size:
            b_idx = 0
        else:
            b_idx = None
        if b_idx is not None:
            dims[b_idx] = bs
        final = path.split("/")[-1]
        ring = final in ("k", "v", "ckv", "kr", "positions")
        w_idx = (b_idx + 1) if (ring and b_idx is not None
                                and ndim > b_idx + 1) else None
        if (seq_shard and mp > 1 and w_idx is not None
                and leaf.shape[w_idx] % mp == 0
                and leaf.shape[w_idx] >= mp * 2048):
            # window-sharded ring (§Perf decode) — only when each shard keeps
            # >=2048 entries; smaller rings (long_500k's 8k SWA cap) pay more
            # in softmax-reduction collectives than the gathers they save
            dims[w_idx] = "model"
            return P(*dims)
        if (ring and final != "positions" and w_idx is not None
                and ndim >= w_idx + 2 and mp > 1
                and leaf.shape[-1] % mp == 0):
            dims[-1] = "model"  # head_dim/latent-sharded (default)
        return P(*dims)

    flat = dict(_flat_paths(cache_tree))
    specs = {p: leaf_spec(p, v) for p, v in flat.items()}
    return _rebuild(cache_tree, specs, "")
