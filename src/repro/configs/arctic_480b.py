"""arctic-480b [moe] — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    d_ff=4864,  # dense-residual FFN width
    vocab_size=32000,
    attention=AttentionConfig(kind="gqa", num_heads=56, num_kv_heads=8,
                              head_dim=128, rope_theta=10000.0),
    moe=MoEConfig(num_experts=128, top_k=2, d_expert_hidden=4864,
                  dense_residual=True, capacity_factor=1.25),
    norm="rmsnorm",
    act="swiglu",
)
