"""Configuration dataclasses for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig` composed of
sub-configs for attention / SSM / MoE blocks.  Configs are frozen dataclasses
so they are hashable (usable as jit static args) and purely declarative —
`repro.models.lm` interprets them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head attention variants: GQA (llama-style) and MLA (DeepSeek-V2)."""

    kind: str = "gqa"  # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # Sliding-window size per layer; None => full causal attention.
    sliding_window: Optional[int] = None
    # Layer indices that use *full* attention even when sliding_window is set
    # (Hymba keeps first/middle/last global).  Empty tuple => all windowed.
    global_layers: Tuple[int, ...] = ()
    # --- MLA-only fields (DeepSeek-V2) ---
    kv_lora_rank: int = 0  # compressed KV latent width (512 for DS-V2)
    q_lora_rank: int = 0  # 0 => full-rank Q projection
    qk_rope_head_dim: int = 64  # decoupled RoPE key width
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence blocks (RWKV6 Finch, Mamba for Hymba)."""

    kind: str = "rwkv6"  # "rwkv6" | "mamba"
    state_size: int = 16  # per-channel state (mamba) / head_dim (rwkv)
    head_dim: int = 64  # rwkv6 head size
    expand: int = 2  # mamba inner expansion
    dt_rank: int = 0  # mamba delta rank; 0 => ceil(d_model/16)
    conv_width: int = 4  # mamba local conv width
    lora_rank: int = 64  # rwkv6 data-dependent decay LoRA rank


@dataclass(frozen=True)
class MoEConfig:
    """Sparsely-gated mixture-of-experts FFN (the paper's subject)."""

    num_experts: int = 8
    top_k: int = 2
    d_expert_hidden: int = 0  # per-expert FFN hidden width
    num_shared_experts: int = 0  # DeepSeek-V2 always-on experts
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # routing variant (the gate is user-swappable, paper §3.1):
    #   "topk"          softmax top-k (gate_policy picks the score order)
    #   "noisy_topk"    Shazeer et al. 2017 learned-noise top-k (exploration)
    #   "gumbel"        gumbel-softmax perturbed top-k (StableMoE-style
    #                   exploration; deterministic == "topk" when no rng)
    #   "expert_choice" Zhou et al. 2022: experts pick tokens — exact
    #                   per-expert capacity by construction (no drops,
    #                   flat load, no balance loss)
    #   "frozen"        StableMoE stage 2: route through the frozen
    #                   distilled router (w_frozen, stop-gradient)
    router: str = "topk"
    router_temperature: float = 1.0  # gumbel perturbation scale
    # "softmax_topk": softmax over all experts then take top-k (GShard)
    # "topk_softmax": top-k logits then softmax over the k (Switch/FastMoE Alg.1)
    gate_policy: str = "softmax_topk"
    renormalize: bool = True  # renormalize selected gate weights to sum to 1
    balance_loss_weight: float = 0.01  # aux load-balance loss (paper §6 future work)
    z_loss_weight: float = 1e-3
    router_dtype: str = "float32"
    # dispatch implementation: "capacity" (static GShard buffers, TPU-native,
    # supports expert parallelism) | "ragged" (sorted tokens + grouped GEMM,
    # FastMoE-faithful single-worker path, no token drops)
    dispatch: str = "capacity"


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper).  Frontend is stubbed: inputs are
    precomputed frame embeddings of shape (B, num_frames, d_model)."""

    num_layers: int = 4
    num_frames: int = 1500  # whisper 30s @ 50Hz after conv frontend


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""  # citation for the assigned config
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32000
    attention: Optional[AttentionConfig] = None
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    # enc-dec / multimodal
    encoder: Optional[EncoderConfig] = None
    # "none" | "audio" (stub frame embeddings) | "vision" (stub patch embeddings)
    frontend: str = "none"
    num_patches: int = 256  # vlm stub patch count
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # remat policy for the scanned layer stack: "full" | "none"
    remat: str = "full"

    # -- derived ------------------------------------------------------------
    @property
    def ffn_kind(self) -> str:
        return "moe" if self.moe is not None else "dense"

    def param_count(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # lm head
        n += self.num_layers * self._layer_params()
        n += self.d_model  # final norm
        if self.encoder is not None:
            enc_layer = self._attn_params(self_only=True) + self._dense_ffn_params(self.d_ff) + 4 * self.d_model
            n += self.encoder.num_layers * enc_layer
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.num_layers * self._layer_params(active=True)
        return n

    # -- internals ------------------------------------------------------------
    def _dense_ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.act == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _attn_params(self, self_only: bool = False) -> int:
        a = self.attention
        if a is None:
            return 0
        if a.kind == "mla":
            kv_in = a.kv_lora_rank + a.qk_rope_head_dim
            q = (self.d_model * a.q_lora_rank + a.q_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.qk_rope_head_dim)
                 if a.q_lora_rank else self.d_model * a.num_heads * (a.qk_nope_head_dim + a.qk_rope_head_dim))
            kv = self.d_model * kv_in + a.kv_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)
            o = a.num_heads * a.v_head_dim * self.d_model
            return q + kv + o
        qkv = self.d_model * (a.num_heads + 2 * a.num_kv_heads) * a.head_dim
        o = a.num_heads * a.head_dim * self.d_model
        cross = 0 if self_only else 0
        return qkv + o + cross

    def _ssm_params(self) -> int:
        s = self.ssm
        if s is None:
            return 0
        d = self.d_model
        if s.kind == "rwkv6":
            # r,k,v,g,o projections + decay/first per head + token-shift loras
            return 5 * d * d + 2 * d + 6 * (d * 32 + 32 * d) + s.lora_rank * 2 * d
        d_in = s.expand * d
        dt_rank = s.dt_rank or max(1, (d + 15) // 16)
        return (d * 2 * d_in + d_in * s.conv_width + d_in * (dt_rank + 2 * s.state_size)
                + dt_rank * d_in + d_in * s.state_size + d_in + d_in * d)

    def _layer_params(self, active: bool = False) -> int:
        n = 2 * self.d_model  # two norms
        n += self._attn_params()
        n += self._ssm_params()
        if self.moe is not None:
            m = self.moe
            per_expert = self._dense_ffn_params(m.d_expert_hidden)
            n_experts = (m.top_k if active else m.num_experts) + m.num_shared_experts
            n += n_experts * per_expert
            n += self.d_model * m.num_experts  # router
            if m.dense_residual:
                n += self._dense_ffn_params(self.d_ff)
        else:
            n += self._dense_ffn_params(self.d_ff)
        return n


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
            max_experts: int = 4) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=512 d_model, <=4 experts)."""
    scale = d_model / cfg.d_model
    attn = cfg.attention
    if attn is not None:
        heads = max(2, min(4, attn.num_heads))
        kv = max(1, min(heads, attn.num_kv_heads if attn.num_kv_heads < attn.num_heads else heads))
        while heads % kv:
            kv -= 1
        attn = dataclasses.replace(
            attn, num_heads=heads, num_kv_heads=kv, head_dim=d_model // heads if attn.kind == "gqa" else attn.head_dim,
            sliding_window=min(attn.sliding_window, 64) if attn.sliding_window else None,
            global_layers=tuple(g for g in attn.global_layers if g < num_layers),
        )
        if attn.kind == "mla":
            attn = dataclasses.replace(
                attn, kv_lora_rank=64, q_lora_rank=32 if cfg.attention.q_lora_rank else 0,
                qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32, head_dim=32)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, head_dim=min(ssm.head_dim, 32), lora_rank=16)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, num_experts=min(moe.num_experts, max_experts),
            top_k=min(moe.top_k, 2),
            d_expert_hidden=max(32, int(moe.d_expert_hidden * scale) // 8 * 8),
            num_shared_experts=min(moe.num_shared_experts, 1))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        d_ff=max(64, int(cfg.d_ff * scale) // 8 * 8),
        vocab_size=min(cfg.vocab_size, 512),
        attention=attn, ssm=ssm, moe=moe,
        encoder=EncoderConfig(num_layers=1, num_frames=16) if cfg.encoder else None,
        num_patches=8 if cfg.frontend == "vision" else cfg.num_patches,
        max_seq_len=512,
        dtype="float32", param_dtype="float32",
        remat="none",
    )
