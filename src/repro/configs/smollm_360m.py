"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49152,
    attention=AttentionConfig(kind="gqa", num_heads=15, num_kv_heads=5,
                              head_dim=64, rope_theta=10000.0),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
)
