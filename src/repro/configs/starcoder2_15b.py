"""starcoder2-15b [dense] — GQA, RoPE, sliding-window 4096 [arXiv:2402.19173]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=40,
    d_model=6144,
    d_ff=24576,
    vocab_size=49152,
    attention=AttentionConfig(kind="gqa", num_heads=48, num_kv_heads=4,
                              head_dim=128, qkv_bias=True, rope_theta=1e5,
                              sliding_window=4096),
    norm="layernorm",
    act="gelu",
)
