"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import (
    AttentionConfig,
    EncoderConfig,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    reduced,
)

from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.internvl2_76b import CONFIG as _internvl
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.fastmoe_gpt import CONFIG as _fastmoe_gpt, DENSE_BASELINE as _fastmoe_dense
from repro.configs.switch_base import CONFIG as _switch

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _granite, _whisper, _arctic, _qwen2, _deepseek,
        _hymba, _rwkv6, _smollm, _internvl, _starcoder2,
        _fastmoe_gpt, _fastmoe_dense, _switch,
    ]
}

# The ten assigned architectures (excludes the paper's own GPT configs).
ASSIGNED = [
    "granite-3-2b", "whisper-tiny", "arctic-480b", "qwen2-72b",
    "deepseek-v2-236b", "hymba-1.5b", "rwkv6-7b", "smollm-360m",
    "internvl2-76b", "starcoder2-15b",
]


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


__all__ = [
    "ARCHS", "ASSIGNED", "AttentionConfig", "EncoderConfig", "INPUT_SHAPES",
    "InputShape", "ModelConfig", "MoEConfig", "SSMConfig", "get_config",
    "reduced",
]
