"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    attention=AttentionConfig(kind="gqa", num_heads=64, num_kv_heads=8,
                              head_dim=128, qkv_bias=True, rope_theta=1e6),
    norm="rmsnorm",
    act="swiglu",
)
