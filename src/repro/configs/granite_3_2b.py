"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    num_layers=40,
    d_model=2048,
    d_ff=8192,
    vocab_size=49155,
    attention=AttentionConfig(kind="gqa", num_heads=32, num_kv_heads=8,
                              head_dim=64, rope_theta=10000.0),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
)
