"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is STUBBED per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings of shape
(B, 1500, 384).  This config describes the transformer backbone only.
"""
from repro.configs.base import AttentionConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,
    d_model=384,
    d_ff=1536,
    vocab_size=51865,
    attention=AttentionConfig(kind="gqa", num_heads=6, num_kv_heads=6,
                              head_dim=64, rope_theta=10000.0),
    norm="layernorm",
    act="gelu",
    encoder=EncoderConfig(num_layers=4, num_frames=1500),
    frontend="audio",
)
