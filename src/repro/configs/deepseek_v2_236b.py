"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    d_ff=12288,  # only used by fmoefy/dense comparisons; all layers are MoE
    vocab_size=102400,
    attention=AttentionConfig(kind="mla", num_heads=128, num_kv_heads=128,
                              head_dim=128, kv_lora_rank=512, q_lora_rank=1536,
                              qk_rope_head_dim=64, qk_nope_head_dim=128,
                              v_head_dim=128, rope_theta=10000.0),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert_hidden=1536,
                  num_shared_experts=2, capacity_factor=1.25),
    norm="rmsnorm",
    act="swiglu",
)
