"""fastmoe-gpt [moe] — the paper's own §5.4 model: 12-layer GPT, 96 experts
per layer, top-2, expert-FFN hidden halved so active FLOPs match the dense
baseline [FastMoE, He et al. 2021, §5.4]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

# Megatron GPT-small-ish geometry used in the paper's 8-GPU experiment.
CONFIG = ModelConfig(
    name="fastmoe-gpt",
    family="moe",
    source="FastMoE §5.4 (arXiv:2103.13262)",
    num_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=50304,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=64, rope_theta=10000.0),
    # d_h halved (4096 -> 2048) so top-2 active FLOPs == dense baseline (§5.4)
    moe=MoEConfig(num_experts=96, top_k=2, d_expert_hidden=2048,
                  capacity_factor=1.25),
    norm="layernorm",
    act="gelu",
)

# Dense same-active-FLOPs baseline the paper compares against in Fig. 7.
DENSE_BASELINE = ModelConfig(
    name="fastmoe-gpt-dense",
    family="dense",
    source="FastMoE §5.4 baseline",
    num_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=50304,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=64, rope_theta=10000.0),
    norm="layernorm",
    act="gelu",
)
