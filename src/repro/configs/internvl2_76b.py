"""internvl2-76b [vlm] — InternViT + LLM backbone [arXiv:2404.16821].

The InternViT vision encoder + MLP projector are STUBBED per the assignment
carve-out: ``input_specs`` supplies precomputed patch embeddings of shape
(B, 256, 8192) which the LM consumes prepended to the text tokens.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=80,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attention=AttentionConfig(kind="gqa", num_heads=64, num_kv_heads=8,
                              head_dim=128, rope_theta=500000.0),
    norm="rmsnorm",
    act="swiglu",
    frontend="vision",
    num_patches=256,
)
