"""switch-base-128 [moe] — Switch Transformer top-1 routing (arXiv:2101.03961).

Beyond the assigned pool: the paper positions FastMoE against Switch/GShard,
so a top-1 (k=1) config exercises the k=1 gate/dispatch/combine path and the
'topk_softmax' policy that Switch uses.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="switch-base-128",
    family="moe",
    source="arXiv:2101.03961",
    num_layers=12,
    d_model=768,
    d_ff=3072,
    vocab_size=32128,
    attention=AttentionConfig(kind="gqa", num_heads=12, num_kv_heads=12,
                              head_dim=64, rope_theta=10000.0),
    moe=MoEConfig(num_experts=128, top_k=1, d_expert_hidden=3072,
                  gate_policy="topk_softmax", renormalize=False,
                  capacity_factor=1.25),
    norm="rmsnorm",
    act="gelu",
)
