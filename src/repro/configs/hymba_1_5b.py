"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676].

Each layer runs GQA attention and a Mamba SSM head in PARALLEL on the same
input and fuses their (normalized) outputs.  Sliding-window attention
everywhere except first/middle/last layers (global), per the paper.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attention=AttentionConfig(kind="gqa", num_heads=25, num_kv_heads=5,
                              head_dim=64, sliding_window=1024,
                              global_layers=(0, 15, 31), rope_theta=10000.0),
    ssm=SSMConfig(kind="mamba", state_size=16, expand=2, conv_width=4),
    norm="rmsnorm",
    act="swiglu",
)
