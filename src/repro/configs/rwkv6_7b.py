"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=None,  # attention-free
    ssm=SSMConfig(kind="rwkv6", head_dim=64, lora_rank=64),
    norm="layernorm",
    act="rwkv",  # squared-relu channel mix
)
