"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

grouped_gemm    — MegaBlocks-style expert-batched GEMM (FMoELinear, C2)
token_shuffle   — scatter/gather row movers (the paper's §4 CUDA kernels)
flash_attention — fused attention (the §Perf-identified memory fix)
ops             — jit'd public wrappers (custom_vjp grouped_matmul, ...)
ref             — pure-jnp oracles asserted against in tests
"""
