"""Pallas grouped GEMM — the TPU-native FMoELinear (paper §3.1/§4, C2).

Computes ``y[i] = x[i] @ w[g(i)]`` for rows ``x`` sorted by group, with every
group's block padded to a multiple of the row tile ``bm`` (see
``repro.core.dispatch.pad_to_tiles``).  One kernel whose grid covers every
(group-row-tile × n-tile × k-tile) replaces FastMoE's CUDA multi-stream
concurrent expert execution: the MXU is time-shared by tiles instead of SMs
being shared by streams.

Tiling: grid (m_tiles, n_tiles, k_tiles), blocks x (bm, bk) / w (1, bk, bn) /
out (bm, bn), f32 accumulator in VMEM scratch; the expert id of each row tile
is scalar-prefetched so the right expert's weight tile streams HBM->VMEM.
VMEM working set = bm*bk + bk*bn + 2*bm*bn floats; defaults (128, 512, 512)
-> ~1.6 MiB, comfortably inside the ~16 MiB/core VMEM budget while keeping
all matmul dims multiples of the 128-lane MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


DEFAULT_BM = 128
DEFAULT_BK = 512
DEFAULT_BN = 512


def _kernel(tile_group_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (m, n, k) grid step: acc += x_tile @ w[g]_tile."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def grouped_gemm_tiled(x: jax.Array, w: jax.Array, tile_group: jax.Array, *,
                       bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                       bn: int = DEFAULT_BN, interpret: bool = False) -> jax.Array:
    """y = x @ w[tile_group[row_tile]] with tile-aligned groups.

    x: (M, K) with M % bm == 0 and rows of one group confined to whole tiles;
    w: (E, K, N); tile_group: (M // bm,) int32.
    """
    M, K = x.shape
    E, K2, N = w.shape
    assert K == K2 and M % bm == 0, (x.shape, w.shape, bm)
    bk = min(bk, K)
    bn = min(bn, N)
    n_m, n_n, n_k = M // bm, pl.cdiv(N, bn), pl.cdiv(K, bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, g: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, g: (g[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, g: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(tile_group, x, w)
