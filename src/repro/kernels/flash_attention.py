"""Pallas flash attention (TPU target) — the fix for the memory-bound
roofline pairs (EXPERIMENTS.md §Perf C): the (S, S) score tile never leaves
VMEM, so the HBM traffic XLA counts for the jnp blockwise scan disappears.

Grid (batch, q_head, q_tiles, kv_tiles) with the kv dim innermost and
sequential; online-softmax stats (m, l) and the output accumulator live in
VMEM scratch across kv steps.  GQA is handled by indexing the kv head as
q_head // (H // KV) in the BlockSpec index maps.  Causal + sliding-window
masking via block-local iota against absolute positions; the window rides in
as a scalar-prefetch arg so one compiled kernel serves every layer of a
mixed-window stack (Hymba).

Block sizes (bq, bk) default 128: VMEM working set =
bq*dk + 2*bk*dk + bq*bk + 2*bq*dv floats ~= 0.4 MiB at dk=dv=128 — far
inside the ~16 MiB budget; MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG = -1e30


def _kernel(win_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, dk)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, dk)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    j_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    dist = i_pos - j_pos
    mask = dist < win_ref[0]
    if causal:
        mask &= dist >= 0
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot(p, v_ref[0, :, 0, :].astype(jnp.float32)))
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: jax.Array | int, causal: bool = True,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """softmax(q k^T / sqrt(dk)) v, fused.

    q: (B, S, H, dk); k, v: (B, Skv, KV, dk|dv) with H % KV == 0;
    S % bq == 0 and Skv % bk == 0 (callers pad; model seqs are powers of 2).
    window: int32 scalar — attend to 0 <= i - j < window (pass >= Skv for
    full attention).
    """
    B, S, H, dk = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, Skv)
    assert S % bq == 0 and Skv % bk == 0, (S, bq, Skv, bk)
    n_q, n_k = S // bq, Skv // bk

    win = jnp.asarray(window, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dk), lambda b, h, i, j, w: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, dk), lambda b, h, i, j, w: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, dv), lambda b, h, i, j, w: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv), lambda b, h, i, j, w: (b, i, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # m
            pltpu.VMEM((bq,), jnp.float32),  # l
            pltpu.VMEM((bq, dv), jnp.float32),  # acc
        ],
    )
    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                               scale=dk ** -0.5)

    def body(win, q, k, v):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, S, H, dv), q.dtype),
            interpret=interpret,
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
        )(win, q, k, v)

    return body(win, q, k, v)
