"""Pure-jnp oracles for every Pallas kernel (asserted against in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """y[i] = x[i] @ w[g(i)], rows sorted by group.  O(E) masked matmuls."""
    E = w.shape[0]
    M = x.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    rows = jnp.arange(M)
    y = jnp.zeros((M, w.shape[2]), jnp.promote_types(x.dtype, w.dtype))
    for e in range(E):
        mask = ((rows >= starts[e]) & (rows < ends[e]))[:, None]
        y = y + jnp.where(mask, x @ w[e], 0.0)
    return y.astype(x.dtype)


def flash_attention_ref(q, k, v, *, window, causal=True):
    """Naive softmax oracle for the flash kernel (f32 throughout)."""
    B, Sq, H, dk = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dk).astype(jnp.float32)
    s = jnp.einsum("bskgd,bckd->bskgc", qg, k.astype(jnp.float32)) * dk ** -0.5
    i = jnp.arange(Sq)[:, None]
    j = jnp.arange(Skv)[None, :]
    mask = (i - j) < window
    if causal:
        mask &= (i - j) >= 0
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgc,bckd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, -1).astype(q.dtype)


def gather_rows_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    return x[idx]


def combine_topk_ref(src: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    gathered = src[idx]  # (T, k, d)
    return jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                      gathered.astype(jnp.float32)).astype(src.dtype)
