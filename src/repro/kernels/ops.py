"""Public jit'd wrappers around the Pallas kernels.

``grouped_matmul`` is differentiable (custom_vjp): both the forward GEMM and
dX reuse the Pallas kernel; dW transposes through ``jax.lax.ragged_dot`` (the
XLA grouped-GEMM primitive) since its reduction layout is rows-major.

On non-TPU backends the kernels run in interpret mode (CPU validation path);
``impl="xla"`` routes everything through ``ragged_dot`` instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dispatch import pad_to_tiles
from repro.kernels import fused_ffn as ff
from repro.kernels import grouped_gemm as gg
from repro.kernels import token_shuffle as ts


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------


def _gm_pallas(x: jax.Array, w: jax.Array, group_sizes: jax.Array,
               bm: int) -> jax.Array:
    """Pad groups to row tiles, run the kernel, un-pad."""
    E = w.shape[0]
    tiled = pad_to_tiles(x, group_sizes, bm, E)
    y_p = gg.grouped_gemm_tiled(tiled.x, w, tiled.tile_group, bm=bm,
                                interpret=_interpret())
    return y_p[tiled.dest]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array,
                   impl: str = "pallas", bm: int = gg.DEFAULT_BM) -> jax.Array:
    """y[i] = x[i] @ w[g(i)] for rows sorted by group.

    x (M, K); w (E, K, N); group_sizes (E,) ints summing to <= M (trailing
    rows beyond the sum get group E-1's weights; callers keep M == sum).
    """
    if impl == "xla":
        return jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32))
    return _gm_pallas(x, w, group_sizes, bm)


def _gm_fwd(x, w, group_sizes, impl, bm):
    return grouped_matmul(x, w, group_sizes, impl, bm), (x, w, group_sizes)


def _gm_bwd(impl, bm, res, dy):
    x, w, group_sizes = res
    # dX: same grouped GEMM against w^T (kernel-served)
    dx = grouped_matmul(dy, w.swapaxes(1, 2), group_sizes, impl, bm)
    # dW[e] = x_e^T @ dy_e: transpose of ragged_dot w.r.t. rhs
    _, vjp_fn = jax.vjp(
        lambda ww: jax.lax.ragged_dot(x, ww, group_sizes.astype(jnp.int32)), w)
    (dw,) = vjp_fn(dy.astype(w.dtype))
    return dx.astype(x.dtype), dw, None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)


# ---------------------------------------------------------------------------
# fused grouped FFN (GEMM1 + activation + GEMM2 in one kernel)
# ---------------------------------------------------------------------------


def ffn_two_pass(x: jax.Array, ws: tuple, wo: jax.Array,
                 group_sizes: jax.Array, act: str = "swiglu",
                 impl: str = "pallas", bm: int = gg.DEFAULT_BM) -> jax.Array:
    """Reference expert FFN as separate grouped GEMMs (materializes (M, H)).

    ws: (wi,) or (wi_gate, wi_up).  This is both the numerical oracle for the
    fused kernel and its backward fallback — the guard keeps forward/backward
    from ever computing different functions.
    """
    ff.check_gating(ws, act)
    if len(ws) == 2:
        h = jax.nn.silu(grouped_matmul(x, ws[0], group_sizes, impl, bm))
        h = h * grouped_matmul(x, ws[1], group_sizes, impl, bm)
    else:
        h = ff._activate(grouped_matmul(x, ws[0], group_sizes, impl, bm),
                         None, act)
    return grouped_matmul(h, wo, group_sizes, impl, bm)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def fused_grouped_ffn(x: jax.Array, ws: tuple, wo: jax.Array,
                      group_sizes: jax.Array, act: str = "swiglu",
                      bm: int = ff.DEFAULT_BM,
                      bh: int = ff.DEFAULT_BH) -> jax.Array:
    """y[i] = act(x[i] @ wi[g(i)]) @ wo[g(i)] with the hidden tile in VMEM.

    Forward runs the fused Pallas kernel (no (M, H) HBM round-trip);
    backward falls back to :func:`ffn_two_pass`, recomputing the hidden
    activation through the grouped-GEMM custom_vjp.
    """
    E = wo.shape[0]
    tiled = pad_to_tiles(x, group_sizes, bm, E)
    y_p = ff.fused_ffn_tiled(tiled.x, ws, wo, tiled.tile_group, act=act,
                             bm=bm, bh=bh, interpret=_interpret())
    return y_p[tiled.dest]


def _ffn_fwd(x, ws, wo, group_sizes, act, bm, bh):
    return fused_grouped_ffn(x, ws, wo, group_sizes, act, bm, bh), (
        x, ws, wo, group_sizes)


def _ffn_bwd(act, bm, bh, res, dy):
    x, ws, wo, group_sizes = res
    _, vjp_fn = jax.vjp(
        lambda x_, ws_, wo_: ffn_two_pass(x_, ws_, wo_, group_sizes, act,
                                          "pallas", bm), x, ws, wo)
    dx, dws, dwo = vjp_fn(dy)
    return dx, dws, dwo, None


fused_grouped_ffn.defvjp(_ffn_fwd, _ffn_bwd)


# ---------------------------------------------------------------------------
# token shuffle
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: jax.Array | int, causal: bool = True,
                    bq: int | None = None, bk: int | None = None) -> jax.Array:
    """Fused flash attention (Pallas TPU kernel; interpret-mode on CPU)."""
    from repro.kernels import flash_attention as fa

    kw = {}
    if bq:
        kw["bq"] = bq
    if bk:
        kw["bk"] = bk
    return fa.flash_attention(q, k, v, window=window, causal=causal,
                              interpret=_interpret(), **kw)


def gather_tokens(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Expert-sort scatter (paper Fig 4): y[i] = x[idx[i]]."""
    return ts.gather_rows(x, idx.astype(jnp.int32), interpret=_interpret())


def combine_tokens(src: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """Gate-weighted un-shuffle (paper Fig 4 gather)."""
    return ts.combine_topk(src, idx.astype(jnp.int32), w, interpret=_interpret())
