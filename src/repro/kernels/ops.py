"""Public jit'd wrappers around the Pallas kernels.

``grouped_matmul`` is differentiable (custom_vjp): both the forward GEMM and
dX reuse the Pallas kernel; dW transposes through ``jax.lax.ragged_dot`` (the
XLA grouped-GEMM primitive) since its reduction layout is rows-major.
``fused_grouped_ffn`` is fully kernel-served in both directions: the forward
fuses GEMM1 + activation + GEMM2 and the backward runs the dX / grouped-dW
kernels of ``repro.kernels.fused_ffn_bwd`` — the (M, H) hidden activation
(and its gradient) never materializes in HBM in either pass.

``aligned=True`` (equal contiguous groups, each a whole number of row
tiles — the capacity path with C % bm == 0) skips the ``pad_to_tiles`` /
``dest``-gather round-trip entirely: the tile→group map is a compile-time
constant and the kernels run on the caller's rows in place.

Partial group sums (``sum(group_sizes) < M``) are a first-class input: the
ragged all-to-all exchange (core/fmoe ``_moe_a2a_ragged``) feeds statically
bounded buffers whose valid prefix is a *traced* row count.  Rows beyond
the sum produce zeros on every impl (pinned explicitly — ``ragged_dot``'s
behavior there is version-dependent and the Pallas kernels would otherwise
run them with the last group's weights), and callers must zero-fill them:
the dW kernels accumulate whole row tiles, so nonzero garbage adjacent to
the last group's valid rows would leak into its weight gradient.

On non-TPU backends the kernels run in interpret mode (CPU validation path);
``impl="xla"`` routes everything through ``ragged_dot`` instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import pad_to_tiles
from repro.kernels import fused_ffn as ff
from repro.kernels import fused_ffn_bwd as fb
from repro.kernels import grouped_gemm as gg
from repro.kernels import token_shuffle as ts


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _aligned_tile_group(M: int, E: int, bm: int) -> jax.Array:
    """Static tile→group map for M rows in E equal contiguous groups.

    Only valid when every group is a whole number of row tiles; then the
    kernels can run on the rows as-is (no pad/scatter, no dest-gather).
    """
    assert M % E == 0 and (M // E) % bm == 0, (M, E, bm)
    return jnp.asarray(np.repeat(np.arange(E, dtype=np.int32),
                                 M // E // bm))


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------


def _zero_invalid(y: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Pin rows beyond ``sum(group_sizes)`` to zero.

    The Pallas path computes them with the last group's weights and
    ``ragged_dot``'s trailing-row contents are version-dependent; the
    bounded ragged-exchange buffers (valid prefix + zero padding) need a
    stable "trailing rows are zero" contract instead.
    """
    valid = jnp.arange(y.shape[0], dtype=jnp.int32) < group_sizes.sum()
    return jnp.where(valid[:, None], y, 0)


def _gm_pallas(x: jax.Array, w: jax.Array, group_sizes: jax.Array,
               bm: int, aligned: bool) -> jax.Array:
    """Pad groups to row tiles, run the kernel, un-pad.

    ``aligned`` skips the round-trip: rows are already tile-aligned (equal
    contiguous groups of M // E rows, each a multiple of ``bm``).
    """
    E = w.shape[0]
    if aligned:
        return gg.grouped_gemm_tiled(x, w, _aligned_tile_group(x.shape[0], E, bm),
                                     bm=bm, interpret=_interpret())
    tiled = pad_to_tiles(x, group_sizes, bm, E)
    y_p = gg.grouped_gemm_tiled(tiled.x, w, tiled.tile_group, bm=bm,
                                interpret=_interpret())
    return _zero_invalid(y_p[tiled.dest], group_sizes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array,
                   impl: str = "pallas", bm: int = gg.DEFAULT_BM,
                   aligned: bool = False) -> jax.Array:
    """y[i] = x[i] @ w[g(i)] for rows sorted by group.

    x (M, K); w (E, K, N); group_sizes (E,) ints summing to <= M.  Rows
    beyond the sum yield zeros (and must be zero-filled for dW correctness
    — see the module docstring); the ragged a2a path relies on this.
    ``aligned`` asserts equal contiguous groups on whole row tiles and skips
    the pad/gather round-trip (the equal-capacity fast path).
    """
    if impl == "xla":
        return _zero_invalid(
            jax.lax.ragged_dot(x, w, group_sizes.astype(jnp.int32)),
            group_sizes)
    return _gm_pallas(x, w, group_sizes, bm, aligned)


def _gm_fwd(x, w, group_sizes, impl, bm, aligned):
    return grouped_matmul(x, w, group_sizes, impl, bm, aligned), (
        x, w, group_sizes)


def _gm_bwd(impl, bm, aligned, res, dy):
    x, w, group_sizes = res
    # dX: same grouped GEMM against w^T (kernel-served)
    dx = grouped_matmul(dy, w.swapaxes(1, 2), group_sizes, impl, bm, aligned)
    # dW[e] = x_e^T @ dy_e: transpose of ragged_dot w.r.t. rhs
    _, vjp_fn = jax.vjp(
        lambda ww: jax.lax.ragged_dot(x, ww, group_sizes.astype(jnp.int32)), w)
    (dw,) = vjp_fn(dy.astype(w.dtype))
    return dx.astype(x.dtype), dw, None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)


# ---------------------------------------------------------------------------
# fused grouped FFN (GEMM1 + activation + GEMM2 in one kernel)
# ---------------------------------------------------------------------------


def ffn_two_pass(x: jax.Array, ws: tuple, wo: jax.Array,
                 group_sizes: jax.Array, act: str = "swiglu",
                 impl: str = "pallas", bm: int = gg.DEFAULT_BM,
                 aligned: bool = False) -> jax.Array:
    """Reference expert FFN as separate grouped GEMMs (materializes (M, H)).

    ws: (wi,) or (wi_gate, wi_up).  This is the numerical oracle for the
    fused kernel (forward AND backward, through the grouped-GEMM custom_vjp).
    """
    ff.check_gating(ws, act)
    if len(ws) == 2:
        h = jax.nn.silu(grouped_matmul(x, ws[0], group_sizes, impl, bm, aligned))
        h = h * grouped_matmul(x, ws[1], group_sizes, impl, bm, aligned)
    else:
        h = ff._activate(grouped_matmul(x, ws[0], group_sizes, impl, bm, aligned),
                         None, act)
    return grouped_matmul(h, wo, group_sizes, impl, bm, aligned)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def fused_grouped_ffn(x: jax.Array, ws: tuple, wo: jax.Array,
                      group_sizes: jax.Array, act: str = "swiglu",
                      bm: int = ff.DEFAULT_BM, bh: int = ff.DEFAULT_BH,
                      aligned: bool = False) -> jax.Array:
    """y[i] = act(x[i] @ wi[g(i)]) @ wo[g(i)] with the hidden tile in VMEM.

    Forward runs the fused Pallas kernel and backward the fused dX / grouped
    dW kernels (repro.kernels.fused_ffn_bwd): a full train step never
    materializes the (M, H) hidden activation or its gradient in HBM.
    ``aligned`` (equal contiguous groups on whole row tiles) skips the
    pad/gather round-trip in both directions.  Rows beyond
    ``sum(group_sizes)`` yield zeros and must arrive zero-filled (module
    docstring) — the ragged a2a's bounded buffers depend on it.
    """
    if aligned:
        tile_group = _aligned_tile_group(x.shape[0], wo.shape[0], bm)
        return ff.fused_ffn_tiled(x, ws, wo, tile_group, act=act, bm=bm,
                                  bh=bh, interpret=_interpret())
    tiled = pad_to_tiles(x, group_sizes, bm, wo.shape[0])
    y_p = ff.fused_ffn_tiled(tiled.x, ws, wo, tiled.tile_group, act=act,
                             bm=bm, bh=bh, interpret=_interpret())
    return _zero_invalid(y_p[tiled.dest], group_sizes)


def _ffn_fwd(x, ws, wo, group_sizes, act, bm, bh, aligned):
    return fused_grouped_ffn(x, ws, wo, group_sizes, act, bm, bh, aligned), (
        x, ws, wo, group_sizes)


def _ffn_bwd(act, bm, bh, aligned, res, dy):
    x, ws, wo, group_sizes = res
    E = wo.shape[0]
    if aligned:
        x_p, dy_p = x, dy
        tile_group = _aligned_tile_group(x.shape[0], E, bm)
    else:
        # same deterministic padded layout as the forward; dy scatters into
        # it (padded rows zero, so they contribute nothing to dX or dW)
        tiled = pad_to_tiles(x, group_sizes, bm, E)
        x_p, tile_group = tiled.x, tiled.tile_group
        dy_p = jnp.zeros((tiled.x.shape[0], dy.shape[1]),
                         dy.dtype).at[tiled.dest].set(dy)
    dx_p = fb.fused_ffn_bwd_dx_tiled(x_p, ws, wo, dy_p, tile_group, act=act,
                                     bm=bm, bh=bh, interpret=_interpret())
    dws, dwo = fb.fused_ffn_bwd_dw_tiled(x_p, ws, wo, dy_p, tile_group,
                                         act=act, bm=bm, bh=bh,
                                         interpret=_interpret())
    if not aligned:
        dx_p = _zero_invalid(dx_p[tiled.dest], group_sizes)
        # groups with no rows own no tiles, so the dW kernel never visits
        # (or zeroes) their blocks — mask the unspecified values out
        nz = (group_sizes > 0)[:, None, None]
        dws = tuple(jnp.where(nz, dw, 0.0) for dw in dws)
        dwo = jnp.where(nz, dwo, 0.0)
    return (dx_p.astype(x.dtype),
            tuple(dw.astype(w.dtype) for dw, w in zip(dws, ws)),
            dwo.astype(wo.dtype), None)


fused_grouped_ffn.defvjp(_ffn_fwd, _ffn_bwd)


# ---------------------------------------------------------------------------
# token shuffle
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: jax.Array | int, causal: bool = True,
                    bq: int | None = None, bk: int | None = None) -> jax.Array:
    """Fused flash attention (Pallas TPU kernel; interpret-mode on CPU)."""
    from repro.kernels import flash_attention as fa

    kw = {}
    if bq:
        kw["bq"] = bq
    if bk:
        kw["bk"] = bk
    return fa.flash_attention(q, k, v, window=window, causal=causal,
                              interpret=_interpret(), **kw)


def gather_tokens(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Expert-sort scatter (paper Fig 4): y[i] = x[idx[i]]."""
    return ts.gather_rows(x, idx.astype(jnp.int32), interpret=_interpret())


def combine_tokens(src: jax.Array, idx: jax.Array, w: jax.Array) -> jax.Array:
    """Gate-weighted un-shuffle (paper Fig 4 gather)."""
    return ts.combine_topk(src, idx.astype(jnp.int32), w, interpret=_interpret())
