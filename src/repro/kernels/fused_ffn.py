"""Fused expert FFN — grouped GEMM1 + activation + grouped GEMM2, one kernel.

``expert_ffn_pallas`` (two-pass) runs the expert FFN as two/three separate
grouped GEMMs, which materializes the (M, H) hidden activation in HBM between
them: at bf16 that is 2*M*H bytes written and read back per layer, pure HBM
traffic the MXU waits on.  This kernel keeps the hidden tile resident in
VMEM: for each row tile (bm rows of one expert ``g``) and each hidden tile
``j`` of width ``bh``,

    h_j   = act(x_tile @ wi[g][:, j])          # (bm, bh), VMEM only
    acc  += h_j @ wo[g][j, :]                  # (bm, N) f32 scratch

so the hidden activation never exists at (M, H) anywhere — only one (bm, bh)
tile at a time, consumed immediately by the second GEMM.  The f32 output
accumulator flushes once per row tile.

Grid (m_tiles, h_tiles): row tiles parallel, hidden tiles sequential
(``arbitrary``) because they accumulate into the same output block.  The
expert id per row tile is scalar-prefetched (same contract as
``grouped_gemm``: rows sorted by group and padded to ``bm`` multiples via
``repro.core.dispatch.pad_to_tiles``).

VMEM working set: x (bm, K) + per-projection weight tiles (K*bh + bh*N) +
f32 acc (bm, N).  Defaults (bm=128, bh=512) with d_model ≤ 2048 stay well
inside the ~16 MiB/core budget.

Backward is fused too (repro.kernels.fused_ffn_bwd wires through the
custom_vjp in repro.kernels.ops): dX and the grouped dW recompute the hidden
tile in VMEM from the saved x, so a full train step never materializes
(M, H) in HBM in either direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_BM = 128
DEFAULT_BH = 512


def check_gating(ws: tuple, act: str) -> None:
    """swiglu needs (wi_gate, wi_up); every other act needs a single (wi,).

    A mismatch either ignores wi_up in forward while the two-pass backward
    uses it, or multiplies by None mid-trace — fail loudly instead.
    """
    if (len(ws) == 2) != (act == "swiglu"):
        raise ValueError(
            f"act='swiglu' requires ws=(wi_gate, wi_up); other activations "
            f"require ws=(wi,) — got {len(ws)} weight(s) with act={act!r}")


def _activate(g: jax.Array, u, act: str) -> jax.Array:
    """Activation between the GEMMs (mirrors repro.core.fmoe._act)."""
    if act == "swiglu":
        return jax.nn.silu(g) * u
    if act == "gelu":
        return jax.nn.gelu(g)
    if act == "rwkv":  # squared relu (RWKV channel-mix)
        return jnp.square(jax.nn.relu(g))
    return jax.nn.silu(g)


def _kernel(tile_group_ref, x_ref, *refs, n_h: int, act: str, gated: bool,
            h_tail: int):
    del tile_group_ref  # consumed by the index maps
    if gated:
        wg_ref, wu_ref, wo_ref, o_ref, acc_ref = refs
    else:
        wg_ref, wo_ref, o_ref, acc_ref = refs
        wu_ref = None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = (jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
         if gated else None)
    # match the two-pass dataflow: the hidden activation is produced at the
    # working dtype (what grouped_matmul would have written to HBM) — here it
    # just never leaves VMEM
    h = _activate(g, u, act).astype(x.dtype)
    wo = wo_ref[0]
    if h_tail:
        # H % bh != 0: the last hidden tile's trailing columns/rows come
        # from out-of-bounds weight reads — unspecified values (NaN in the
        # interpreter, garbage on TPU).  Mask BOTH sides of the contraction:
        # a zeroed h column times a NaN wo row would still be NaN.
        limit = jnp.where(pl.program_id(1) == n_h - 1, h_tail, h.shape[1])
        col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
        h = jnp.where(col < limit, h, jnp.zeros_like(h))
        row = jax.lax.broadcasted_iota(jnp.int32, wo.shape, 0)
        wo = jnp.where(row < limit, wo, jnp.zeros_like(wo))
    acc_ref[...] += jnp.dot(h, wo, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_h - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bh", "interpret"))
def fused_ffn_tiled(x: jax.Array, ws: tuple, wo: jax.Array,
                    tile_group: jax.Array, *, act: str = "swiglu",
                    bm: int = DEFAULT_BM, bh: int = DEFAULT_BH,
                    interpret: bool = False) -> jax.Array:
    """y = (act(x @ wi[g]) [* gate]) @ wo[g] with tile-aligned groups.

    x: (M, K), M % bm == 0, rows of one group confined to whole tiles;
    ws: (wi,) or (wi_gate, wi_up) each (E, K, H); wo: (E, H, N);
    tile_group: (M // bm,) int32 expert id per row tile.
    """
    M, K = x.shape
    E, K2, H = ws[0].shape
    E2, H2, N = wo.shape
    assert K == K2 and H == H2 and E == E2 and M % bm == 0, (
        x.shape, ws[0].shape, wo.shape, bm)
    check_gating(ws, act)
    gated = len(ws) == 2
    bh = min(bh, H)
    n_m, n_h = M // bm, pl.cdiv(H, bh)

    wi_spec = pl.BlockSpec((1, K, bh), lambda i, j, g: (g[i], 0, j))
    in_specs = [pl.BlockSpec((bm, K), lambda i, j, g: (i, 0))]
    in_specs += [wi_spec] * len(ws)
    in_specs += [pl.BlockSpec((1, bh, N), lambda i, j, g: (g[i], j, 0))]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_h),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, N), lambda i, j, g: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_h=n_h, act=act, gated=gated,
                          h_tail=H % bh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(tile_group, x, *ws, wo)
