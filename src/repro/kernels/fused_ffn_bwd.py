"""Fused expert-FFN backward — dX and grouped dW without the (M, H) hidden.

``fused_ffn.fused_ffn_tiled`` removed the (M, H) HBM round-trip from the
*forward*; until this module existed the custom_vjp fell back to the two-pass
grouped GEMMs, so every training step still materialized the hidden
activation (and its gradient) at (M, H) in HBM and paid two extra grouped
GEMMs of recompute.  Training is FastMoE's whole point (§4–5), so the
backward gets the same treatment: for each row tile (bm rows of one expert
``g``) and hidden tile ``j`` of width ``bh``, both kernels recompute the
hidden tile in VMEM from the saved x and consume it immediately —

dX kernel (grid (m_tiles, h_tiles), row tiles parallel, hidden sequential):

    g_j, u_j = x @ wi[g][:, j], x @ wi_up[g][:, j]   # (bm, bh), VMEM only
    dh_j     = dy @ wo[g][j, :]^T                    # (bm, bh), VMEM only
    dg_j,du_j= vjp(act)(g_j, u_j)(dh_j)              # exact act gradient
    acc     += dg_j @ wi[g][:, j]^T [+ du_j @ ...]   # (bm, K) f32 scratch

dW kernel (grid (h_tiles, m_tiles): row tiles *inner* so each expert's
(dwi[:, j] / dwo[j, :]) output block is visited by consecutive grid steps and
accumulates in VMEM across that expert's row tiles, f32):

    dwo[g][j, :] += h_j^T @ dy
    dwi[g][:, j] += x^T @ dg_j        (and dwi_up += x^T @ du_j)

Neither the hidden tile nor its gradient ever exists at (M, H) anywhere.
The activation gradient goes through ``jax.vjp`` of the *same*
``fused_ffn._activate`` the forward runs, so swiglu/gelu/rwkv/silu backward
is exact by construction (including gelu's tanh approximation).

Tail tiles (H % bh != 0) mask both sides of every contraction, like the
forward: out-of-bounds weight reads are unspecified (NaN in the
interpreter), and NaN * 0 is still NaN.

VMEM working set (dX): x (bm, K) + dy (bm, N) + weight tiles
(len(ws)*K*bh + bh*N) + f32 acc (bm, K); dW additionally holds the f32
output blocks (len(ws)*K*bh + bh*N).  With the defaults (bm=128, bh=512)
shrink ``bh`` for d_model > 1024 to stay inside the ~16 MiB/core budget.

``repro.kernels.ops`` wires both into ``fused_grouped_ffn``'s custom_vjp
(padding/unpadding rows via ``pad_to_tiles`` exactly like the forward) and
masks the dW of empty groups, whose output blocks no grid step visits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import fused_ffn as ff


def _hidden_and_grads(x, dy, wg_ref, wu_ref, wo_ref, *, act, gated, h_tail,
                      j, n_h):
    """Shared per-tile recompute: hidden tile, dh, and activation grads.

    Returns (h, dg, du) with tail columns (and the weight tiles feeding dX)
    already masked; h is cast to x.dtype exactly like the forward.
    """
    g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u = (jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
         if gated else None)
    # dh = dy @ wo^T, contracting the output dim — (bm, bh), VMEM only
    dh = jax.lax.dot_general(dy, wo_ref[0], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if gated:
        h, act_vjp = jax.vjp(lambda a, b: ff._activate(a, b, act), g, u)
        dg, du = act_vjp(dh)
    else:
        h, act_vjp = jax.vjp(lambda a: ff._activate(a, None, act), g)
        (dg,), du = act_vjp(dh), None
    if h_tail:
        # last hidden tile: columns past H came from out-of-bounds weight
        # reads (unspecified values) — zero every tail column before it can
        # poison a contraction (NaN * 0 == NaN)
        limit = jnp.where(j == n_h - 1, h_tail, h.shape[1])
        col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
        valid = col < limit
        h = jnp.where(valid, h, 0.0)
        dg = jnp.where(valid, dg, 0.0)
        if gated:
            du = jnp.where(valid, du, 0.0)
    return h.astype(x.dtype), dg, du


def _tail_mask_w(w, h_tail, j, n_h):
    """Zero the tail columns of a (K, bh) weight tile (rows of w^T)."""
    if not h_tail:
        return w
    limit = jnp.where(j == n_h - 1, h_tail, w.shape[1])
    col = jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    return jnp.where(col < limit, w, jnp.zeros_like(w))


def _dx_kernel(tile_group_ref, x_ref, dy_ref, *refs, n_h: int, act: str,
               gated: bool, h_tail: int):
    del tile_group_ref  # consumed by the index maps
    if gated:
        wg_ref, wu_ref, wo_ref, dx_ref, acc_ref = refs
    else:
        wg_ref, wo_ref, dx_ref, acc_ref = refs
        wu_ref = None
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    dy = dy_ref[...]
    _, dg, du = _hidden_and_grads(x, dy, wg_ref, wu_ref, wo_ref, act=act,
                                  gated=gated, h_tail=h_tail, j=j, n_h=n_h)
    # dX += dg @ wi^T (contract the hidden dim); the hidden-grad tile is
    # consumed here and never leaves VMEM
    wg = _tail_mask_w(wg_ref[0], h_tail, j, n_h)
    acc_ref[...] += jax.lax.dot_general(
        dg.astype(x.dtype), wg, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if gated:
        wu = _tail_mask_w(wu_ref[0], h_tail, j, n_h)
        acc_ref[...] += jax.lax.dot_general(
            du.astype(x.dtype), wu, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == n_h - 1)
    def _flush():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def _dw_kernel(tile_group_ref, x_ref, dy_ref, *refs, n_h: int, act: str,
               gated: bool, h_tail: int):
    if gated:
        wg_ref, wu_ref, wo_ref, dwg_ref, dwu_ref, dwo_ref = refs
    else:
        wg_ref, wo_ref, dwg_ref, dwo_ref = refs
        wu_ref = dwu_ref = None
    j = pl.program_id(0)
    i = pl.program_id(1)
    # first row tile of this expert's block: zero the freshly-mapped output
    # blocks (they accumulate in VMEM across the group's consecutive tiles)
    first = (i == 0) | (tile_group_ref[i]
                        != tile_group_ref[jnp.maximum(i - 1, 0)])

    @pl.when(first)
    def _init():
        dwg_ref[...] = jnp.zeros_like(dwg_ref)
        dwo_ref[...] = jnp.zeros_like(dwo_ref)
        if gated:
            dwu_ref[...] = jnp.zeros_like(dwu_ref)

    x = x_ref[...]
    dy = dy_ref[...]
    h, dg, du = _hidden_and_grads(x, dy, wg_ref, wu_ref, wo_ref, act=act,
                                  gated=gated, h_tail=h_tail, j=j, n_h=n_h)
    # dwo[j, :] += h^T @ dy ; dwi[:, j] += x^T @ dg  (contract the rows);
    # padded rows are zero in BOTH x and dy, so they contribute nothing
    dwo_ref[...] += jax.lax.dot_general(
        h, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]
    dwg_ref[...] += jax.lax.dot_general(
        x, dg.astype(x.dtype), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]
    if gated:
        dwu_ref[...] += jax.lax.dot_general(
            x, du.astype(x.dtype), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]


def _common_dims(x, ws, wo, dy, bm, bh):
    M, K = x.shape
    E, K2, H = ws[0].shape
    E2, H2, N = wo.shape
    M2, N2 = dy.shape
    assert (K == K2 and H == H2 and E == E2 and M == M2 and N == N2
            and M % bm == 0), (x.shape, ws[0].shape, wo.shape, dy.shape, bm)
    bh = min(bh, H)
    return M, K, H, N, E, bh, M // bm, pl.cdiv(H, bh)


def _wi_spec(K, bh, index_map):
    return pl.BlockSpec((1, K, bh), index_map)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bh", "interpret"))
def fused_ffn_bwd_dx_tiled(x: jax.Array, ws: tuple, wo: jax.Array,
                           dy: jax.Array, tile_group: jax.Array, *,
                           act: str = "swiglu", bm: int = ff.DEFAULT_BM,
                           bh: int = ff.DEFAULT_BH,
                           interpret: bool = False) -> jax.Array:
    """dX for y = act(x @ wi[g]) @ wo[g], hidden/dhidden tiles VMEM-only.

    Same tiling contract as ``fused_ffn_tiled``: rows sorted by group and
    padded to ``bm`` multiples, ``tile_group`` scalar-prefetched.
    """
    ff.check_gating(ws, act)
    gated = len(ws) == 2
    M, K, H, N, E, bh, n_m, n_h = _common_dims(x, ws, wo, dy, bm, bh)

    in_specs = [pl.BlockSpec((bm, K), lambda i, j, g: (i, 0)),
                pl.BlockSpec((bm, N), lambda i, j, g: (i, 0))]
    in_specs += [_wi_spec(K, bh, lambda i, j, g: (g[i], 0, j))] * len(ws)
    in_specs += [pl.BlockSpec((1, bh, N), lambda i, j, g: (g[i], j, 0))]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_h),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, K), lambda i, j, g: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_dx_kernel, n_h=n_h, act=act, gated=gated,
                          h_tail=H % bh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, K), x.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(tile_group, x, dy, *ws, wo)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bh", "interpret"))
def fused_ffn_bwd_dw_tiled(x: jax.Array, ws: tuple, wo: jax.Array,
                           dy: jax.Array, tile_group: jax.Array, *,
                           act: str = "swiglu", bm: int = ff.DEFAULT_BM,
                           bh: int = ff.DEFAULT_BH, interpret: bool = False):
    """Grouped (dwi..., dwo) in f32, hidden tiles recomputed in VMEM.

    Row tiles are the *inner* grid dim so each expert's weight-grad block is
    revisited by consecutive steps only (the legal Pallas accumulation
    pattern).  Blocks of groups that own no row tiles are never written —
    the caller masks empty groups (``repro.kernels.ops`` does).
    """
    ff.check_gating(ws, act)
    gated = len(ws) == 2
    M, K, H, N, E, bh, n_m, n_h = _common_dims(x, ws, wo, dy, bm, bh)

    in_specs = [pl.BlockSpec((bm, K), lambda j, i, g: (i, 0)),
                pl.BlockSpec((bm, N), lambda j, i, g: (i, 0))]
    in_specs += [_wi_spec(K, bh, lambda j, i, g: (g[i], 0, j))] * len(ws)
    in_specs += [pl.BlockSpec((1, bh, N), lambda j, i, g: (g[i], j, 0))]
    dwi_spec = _wi_spec(K, bh, lambda j, i, g: (g[i], 0, j))
    out_specs = [dwi_spec] * len(ws)
    out_specs += [pl.BlockSpec((1, bh, N), lambda j, i, g: (g[i], j, 0))]
    out_shape = [jax.ShapeDtypeStruct((E, K, H), jnp.float32)] * len(ws)
    out_shape += [jax.ShapeDtypeStruct((E, H, N), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_h, n_m),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
    )
    outs = pl.pallas_call(
        functools.partial(_dw_kernel, n_h=n_h, act=act, gated=gated,
                          h_tail=H % bh),
        grid_spec=grid_spec,
        out_shape=tuple(out_shape),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(tile_group, x, dy, *ws, wo)
    return tuple(outs[:len(ws)]), outs[len(ws)]
