"""Pallas token scatter/gather kernels — the paper's §4 dedicated memory-
movement CUDA kernels, re-tiled for TPU.

``gather_rows``  : y[i] = x[idx[i]]            (the *scatter* step of Fig 4 —
                   tokens gathered into expert-sorted order)
``combine_topk`` : y[t] = sum_k w[t,k] * src[idx[t,k]]   (the *gather* step —
                   expert outputs back in original order, mixed by the gate)

Row indices are scalar-prefetched so each grid step's BlockSpec index_map
resolves the source row before the block DMA is issued — the TPU analogue of
coalesced global-memory indexing.  Blocks are (1, d_model): one token row per
grid step, lane dim = d_model (>=128 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(x: jax.Array, idx: jax.Array, *, interpret: bool = False) -> jax.Array:
    """y[i] = x[idx[i]] ; x (M, d), idx (T,) int32 -> (T, d)."""
    T = idx.shape[0]
    d = x.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(idx, x)


def _combine_kernel(idx_ref, w_ref, *refs, k: int):
    srcs, o_ref = refs[:k], refs[k]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for slot in range(k):
        acc += w_ref[0, slot].astype(jnp.float32) * srcs[slot][...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def combine_topk(src: jax.Array, idx: jax.Array, w: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """y[t] = sum_k w[t, k] * src[idx[t, k]].

    src (M, d) expert outputs; idx (T, k) int32 rows; w (T, k) weights.
    The k source rows of one output row arrive as k separate (1, d) blocks,
    each with its own scalar-prefetched index map.
    """
    T, k = idx.shape
    d = src.shape[1]
    in_specs = [
        pl.BlockSpec((1, k), lambda i, idx: (i, 0)),  # weights row
    ] + [
        pl.BlockSpec((1, d), functools.partial(
            lambda i, idx, slot=None: (idx[i, slot], 0), slot=s))
        for s in range(k)
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_combine_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), src.dtype),
        interpret=interpret,
    )(idx, w, *([src] * k))
