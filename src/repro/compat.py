"""jax-version compatibility shims.

The codebase targets current jax APIs; this module papers over the renames
between jax 0.4.x and newer releases so the same source runs on both:

* ``shard_map`` — ``jax.shard_map(..., check_vma=)`` vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=)``;
* ``make_mesh`` / ``make_abstract_mesh`` — the ``axis_types=`` kwarg (and the
  ``AxisType`` enum) only exist on newer jax; old ``AbstractMesh`` takes a
  ``((name, size), ...)`` shape tuple;
* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` was spelled
  ``pltpu.TPUCompilerParams`` before the rename;
* ``ragged_all_to_all_shards`` — ``jax.lax.ragged_all_to_all`` as the wire
  transport for valid-prefix per-peer shards where the jax version has it,
  dense bounded-shard all-to-all elsewhere (bit-identical results).

Keep every fallback import lazy so importing this module never touches jax
device state (the dry-run contract of launch/mesh.py).
"""
from __future__ import annotations

from typing import Any

import jax

# Oldest jax release the shims in this module target.  CI's version matrix
# reads this pin (.github/workflows/ci.yml greps it) and runs the full
# tier-1 subset against it next to the latest release, so the fallback
# branches below are tested instead of trusted.
MIN_JAX_VERSION = "0.4.37"

try:  # newer jax
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the old-jax spelling as fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # pragma: no cover
            pass
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple, axes: tuple) -> Any:
    """AbstractMesh across the signature change (old: ((name, size), ...))."""
    from jax.sharding import AbstractMesh
    if AxisType is not None:
        try:
            return AbstractMesh(shape, axes,
                                axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # pragma: no cover
            pass
    return AbstractMesh(tuple(zip(axes, shape)))


def axis_size(axis) -> int:
    """Static size of a (possibly tuple) mapped mesh axis.

    ``jax.lax.axis_size`` only exists on newer jax; 0.4.x exposes the bound
    frame via ``jax.core.axis_frame`` (which returns the size itself there).
    Must be called under a shard_map/pmap binding of ``axis``.
    """
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    import jax.core as _core
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        frame = _core.axis_frame(a)
        n *= int(getattr(frame, "size", frame))
    return n


def has_ragged_all_to_all() -> bool:
    """True when this jax exposes the native ``lax.ragged_all_to_all``."""
    return hasattr(jax.lax, "ragged_all_to_all")


def ragged_all_to_all_shards(send, send_sizes, recv_sizes, axis, *,
                             force_fallback: bool = False):
    """Exchange ``(mp, bound, ...)`` per-peer shards, valid-prefix ragged.

    ``send[p, :send_sizes[p]]`` are the rows for peer ``p`` (zero padding
    after); the result holds ``recv[s, :recv_sizes[s]]`` rows from source
    ``s`` (zero padding after) — i.e. exactly what a dense tiled dim-0
    all-to-all of the padded shards returns when padding is zeros.

    On jax versions with ``lax.ragged_all_to_all`` the native primitive is
    the wire transport, so only the valid prefixes cross the wire; elsewhere
    (and under ``force_fallback``) the dense bounded-shard all-to-all moves
    the full static buffer.  Both branches return bit-identical arrays
    (tests/test_hier_a2a.py compares them), so callers never see which
    transport ran.
    """
    import jax.numpy as jnp
    mp, bound = send.shape[0], send.shape[1]
    if has_ragged_all_to_all() and not force_fallback:
        flat = send.reshape(mp * bound, *send.shape[2:])
        out = jnp.zeros_like(flat)
        offs = (jnp.arange(mp, dtype=jnp.int32) * bound)
        # my segment for peer p starts at p*bound locally and must land at
        # slot (my_rank * bound) on peer p — the same place the dense
        # exchange concatenates it
        my = jax.lax.axis_index(axis).astype(jnp.int32) * bound
        out = jax.lax.ragged_all_to_all(
            flat, out, offs, jnp.asarray(send_sizes, jnp.int32),
            jnp.full((mp,), my, jnp.int32),
            jnp.asarray(recv_sizes, jnp.int32), axis_name=axis)
        return out.reshape(send.shape)
    del send_sizes, recv_sizes  # fallback moves the full static shards
    return jax.lax.all_to_all(send, axis, 0, 0, tiled=True)


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams | pltpu.TPUCompilerParams, whichever exists."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(**kwargs)
