"""jax-version compatibility shims.

The codebase targets current jax APIs; this module papers over the renames
between jax 0.4.x and newer releases so the same source runs on both:

* ``shard_map`` — ``jax.shard_map(..., check_vma=)`` vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=)``;
* ``make_mesh`` / ``make_abstract_mesh`` — the ``axis_types=`` kwarg (and the
  ``AxisType`` enum) only exist on newer jax; old ``AbstractMesh`` takes a
  ``((name, size), ...)`` shape tuple;
* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` was spelled
  ``pltpu.TPUCompilerParams`` before the rename.

Keep every fallback import lazy so importing this module never touches jax
device state (the dry-run contract of launch/mesh.py).
"""
from __future__ import annotations

from typing import Any

import jax

# Oldest jax release the shims in this module target.  CI's version matrix
# reads this pin (.github/workflows/ci.yml greps it) and runs the full
# tier-1 subset against it next to the latest release, so the fallback
# branches below are tested instead of trusted.
MIN_JAX_VERSION = "0.4.37"

try:  # newer jax
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the old-jax spelling as fallback."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # pragma: no cover
            pass
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple, axes: tuple) -> Any:
    """AbstractMesh across the signature change (old: ((name, size), ...))."""
    from jax.sharding import AbstractMesh
    if AxisType is not None:
        try:
            return AbstractMesh(shape, axes,
                                axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # pragma: no cover
            pass
    return AbstractMesh(tuple(zip(axes, shape)))


def axis_size(axis) -> int:
    """Static size of a (possibly tuple) mapped mesh axis.

    ``jax.lax.axis_size`` only exists on newer jax; 0.4.x exposes the bound
    frame via ``jax.core.axis_frame`` (which returns the size itself there).
    Must be called under a shard_map/pmap binding of ``axis``.
    """
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    import jax.core as _core
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        frame = _core.axis_frame(a)
        n *= int(getattr(frame, "size", frame))
    return n


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams | pltpu.TPUCompilerParams, whichever exists."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(**kwargs)
