"""Full language models assembled from configs.

Public API (all functional):
  init_params(rng, cfg)                        -> params pytree
  forward(params, cfg, tokens, ...)            -> (logits, MoEMetrics)
  loss_fn(params, cfg, batch, ...)             -> (loss, aux dict)
  init_cache(cfg, batch, cache_len, ...)       -> stacked decode cache
  decode_step(params, cfg, tokens, pos, cache) -> (logits, new_cache, metrics)

The layer stack is stored stacked (leading L dim on every leaf) and applied
with jax.lax.scan (+ jax.remat per layer when cfg.remat == "full") — essential
for compile time at 80 layers x 512 devices.  Per-layer sliding windows ride
along as a scanned (L,) array so Hymba's global layers coexist with windowed
ones inside one homogeneous scan.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.balance import MoEMetrics
from repro.core.fmoe import DistConfig
from repro.models import attention as A
from repro.models import blocks as B
from repro.models.layers import (apply_norm, embed_init, embed_lookup,
                                 linear, linear_init, norm_init, unembed)


def _n_experts(cfg: ModelConfig) -> int:
    return cfg.moe.num_experts if cfg.moe is not None else 1


def _layer_tables(cfg: ModelConfig, dist: Optional[DistConfig]):
    """Split a per-layer placement riding on ``dist`` for the layer scan.

    A :class:`repro.placement.plan.PerLayerPlacement` can't pass into
    fmoe_apply whole (each layer has its own gate-id table but the scan
    needs one static geometry), so it splits here: ``dist.placement``
    becomes the shared-geometry representative plan, and the stacked
    ``(L, E)`` logical->physical tables return separately to ride the scan
    as per-layer xs (blocks._apply_ffn threads each row as ``l2p``).
    Returns ``(dist, tables | None)``.
    """
    if dist is None or dist.placement is None:
        return dist, None
    from repro.placement.plan import PerLayerPlacement
    place = dist.placement
    if not isinstance(place, PerLayerPlacement):
        return dist, None
    place.validate()
    if place.num_layers != cfg.num_layers:
        raise ValueError(
            f"per-layer placement has {place.num_layers} layers, "
            f"config has {cfg.num_layers}")
    tables = jnp.asarray(place.logical_to_physical, jnp.int32)  # (L, E)
    return dist._replace(placement=place.geometry), tables


def _cast_params(p, dtype):
    """Cast float params to the compute dtype at point of use (master weights
    stay float32; the router re-promotes to f32 internally)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        and a.dtype != dtype else a, p)


def _stacked_layer_init(rng: jax.Array, cfg: ModelConfig, n: int,
                        cross: bool = False) -> dict:
    keys = jax.random.split(rng, n)
    return jax.vmap(lambda k: B.layer_init(k, cfg, cross=cross))(keys)


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 6)
    dtype = jnp.dtype(cfg.param_dtype)
    p: dict = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "layers": _stacked_layer_init(ks[1], cfg, cfg.num_layers,
                                      cross=cfg.family == "audio"),
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.encoder is not None:
        p["enc_layers"] = _stacked_layer_init(ks[3], cfg, cfg.encoder.num_layers)
        p["enc_norm"] = norm_init(cfg.d_model, cfg.norm)
    return p


def _logits(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return linear(params["lm_head"], x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Encoder (whisper) — bidirectional stack over stubbed frame embeddings
# ---------------------------------------------------------------------------


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) precomputed conv-frontend embeddings (stub)."""
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, p_l):
        p_l = _cast_params(p_l, jnp.dtype(cfg.dtype))
        h = A.gqa_apply(p_l["attn"], apply_norm(p_l["norm1"], x, cfg.norm),
                        cfg.attention, window=B.FULL_WINDOW, causal=False)
        x = x + h
        from repro.core.fmoe import dense_ffn
        h = dense_ffn(p_l["ffn"], apply_norm(p_l["norm2"], x, cfg.norm), cfg.act)
        return (x + h).astype(x.dtype), None

    if cfg.remat == "full":
        body = jax.remat(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            frames: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None,
            dist: Optional[DistConfig] = None, impl: str = "einsum",
            layer_loads: bool = False, rng: Optional[jax.Array] = None):
    """tokens (B, S) -> (logits (B, S', V), MoEMetrics).

    vlm: ``patches`` (B, P, d) are prepended; logits cover the full combined
    sequence (caller slices text positions for the loss).
    audio: ``frames`` (B, F, d) go through the encoder; decoder cross-attends.
    ``layer_loads=True`` additionally returns the per-layer expert load
    stack (L, E) — expert skew is per layer, and the per-layer placement
    planner feeds on this instead of the layer-summed ``metrics.load``.
    ``rng`` arms gate exploration (noisy_topk / gumbel routers): it splits
    into per-layer keys riding the layer scan; None keeps routing
    deterministic (the eval/serve stance for every router).
    """
    dtype = jnp.dtype(cfg.dtype)
    dist, tables = _layer_tables(cfg, dist)
    x = embed_lookup(params["embed"], tokens, dtype)
    if cfg.frontend == "vision" and patches is not None:
        x = jnp.concatenate([patches.astype(dtype), x], axis=1)
    enc_out = None
    if cfg.family == "audio":
        enc_out = encode(params, cfg, frames)

    batch = x.shape[0]
    windows = B.layer_windows(cfg)
    state0 = B.mixer_state(cfg, batch, dtype)
    n_e = _n_experts(cfg)
    want_loads = layer_loads and cfg.moe is not None
    has_rng = rng is not None and cfg.moe is not None

    def body(carry, xs):
        x, metrics = carry
        p_l, window = xs[:2]
        rest = xs[2:]
        l2p = rest[0] if tables is not None else None
        rng_l = rest[int(tables is not None)] if has_rng else None
        x, m = B.layer_apply_seq(_cast_params(p_l, dtype), cfg, x,
                                 window=window, dist=dist,
                                 enc_out=enc_out, mixer_state=state0,
                                 impl=impl, l2p=l2p, rng=rng_l)
        metrics = metrics + m if m is not None else metrics
        return ((x.astype(dtype), metrics),
                m.load if want_loads else None)

    if cfg.remat == "full":
        body = jax.remat(body)
    xs = (params["layers"], windows)
    if tables is not None:
        xs += (tables,)
    if has_rng:
        xs += (jax.random.split(rng, cfg.num_layers),)
    (x, metrics), loads = jax.lax.scan(body, (x, MoEMetrics.zero(n_e)), xs)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _logits(params, cfg, x)
    if layer_loads:
        if loads is None:
            loads = jnp.zeros((cfg.num_layers, n_e))
        return logits, metrics, loads
    return logits, metrics


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            dist: Optional[DistConfig] = None, impl: str = "einsum",
            rng: Optional[jax.Array] = None):
    """Next-token cross-entropy + MoE aux losses.  batch: {"tokens", and
    optionally "frames"/"patches"}.  ``impl`` picks the expert kernels
    (einsum | pallas | fused — see repro.core.fmoe.EXPERT_FNS).  ``rng``
    arms train-time gate exploration (see :func:`forward`)."""
    tokens = batch["tokens"]
    logits, metrics, loads = forward(params, cfg, tokens,
                                     frames=batch.get("frames"),
                                     patches=batch.get("patches"), dist=dist,
                                     impl=impl, layer_loads=True, rng=rng)
    if cfg.frontend == "vision" and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]  # text positions only
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    loss = ce
    if cfg.moe is not None:
        L = cfg.num_layers
        loss = loss + (cfg.moe.balance_loss_weight * metrics.aux_loss
                       + cfg.moe.z_loss_weight * metrics.z_loss) / L
    L = max(cfg.num_layers, 1)
    aux = {"ce": ce, "aux_loss": metrics.aux_loss, "z_loss": metrics.z_loss,
           "drop_frac": metrics.drop_frac / L,
           "load": metrics.load / L,  # per-expert load for the §6 monitor
           "load_layers": loads}  # (L, E) per-layer load (per-layer planner)
    obs = metrics.obs
    if obs is not None:
        # device-side telemetry (repro.obs.counters), summed over layers —
        # rides the same device->host transfer as the loss
        aux.update(wire_elems=obs.wire_elems, wire_bytes=obs.wire_bytes,
                   wire_bytes_intra=obs.wire_bytes_intra,
                   wire_bytes_inter=obs.wire_bytes_inter,
                   dropped=obs.dropped, shadow_hits=obs.shadow_hits,
                   imbalance=obs.imbalance / L)  # per-layer avg
    return loss, aux


# ---------------------------------------------------------------------------
# Prefill: one full pass that fills the decode cache (serving fast path)
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: Any, *,
            frames: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None,
            dist: Optional[DistConfig] = None, impl: str = "einsum"):
    """tokens (B, S) + empty cache -> (logits (B, S', V), filled cache,
    metrics).  Decoding then continues at position S' with decode_step."""
    dtype = jnp.dtype(cfg.dtype)
    dist, tables = _layer_tables(cfg, dist)
    x = embed_lookup(params["embed"], tokens, dtype)
    if cfg.frontend == "vision" and patches is not None:
        x = jnp.concatenate([patches.astype(dtype), x], axis=1)
    if cfg.family == "audio":
        enc_out = encode(params, cfg, frames)
        L = cfg.num_layers
        cache = dict(cache)
        cache["enc_out"] = jnp.broadcast_to(
            enc_out[None].astype(dtype), (L,) + enc_out.shape)

    windows = B.layer_windows(cfg)
    n_e = _n_experts(cfg)

    def body(carry, xs):
        x, metrics = carry
        p_l, window, cache_l = xs[:3]
        l2p = xs[3] if tables is not None else None
        x, new_cache_l, m = B.layer_apply_prefill(
            _cast_params(p_l, dtype), cfg, x, cache_l, window=window,
            dist=dist, impl=impl, l2p=l2p)
        metrics = metrics + m if m is not None else metrics
        return (x.astype(dtype), metrics), new_cache_l

    xs = (params["layers"], windows, cache)
    if tables is not None:
        xs += (tables,)
    (x, metrics), new_cache = jax.lax.scan(
        body, (x, MoEMetrics.zero(n_e)), xs)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return _logits(params, cfg, x), new_cache, metrics


# ---------------------------------------------------------------------------
# Decode (one token, KV/state cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
               enc_out: Optional[jax.Array] = None) -> Any:
    """Stacked (leading L dim) decode cache for the layer stack."""
    dtype = jnp.dtype(cfg.dtype)
    one = B.layer_cache(cfg, batch, cache_len, dtype, enc_out=enc_out)
    L = cfg.num_layers
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)


def supports_paged(cfg: ModelConfig) -> bool:
    """Whether the family's decode cache can be paged (plain attention
    ring caches; recurrent/hybrid/audio state caches cannot)."""
    return (cfg.attention is not None
            and cfg.family not in ("ssm", "hybrid", "audio"))


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int) -> Any:
    """Stacked (leading L dim) paged block pool shared by all decode slots.

    Pool leaves have no batch dim — slots address it through per-slot block
    tables passed to ``decode_step(block_tables=...)``.  Rows 0/1 are the
    reserved null/scratch blocks (models/attention)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV cache is not supported for family {cfg.family!r}")
    dtype = jnp.dtype(cfg.dtype)
    one = B.layer_paged_cache(cfg, num_blocks, block_size, dtype)
    L = cfg.num_layers
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                pos: jax.Array, cache: Any, *,
                dist: Optional[DistConfig] = None, impl: str = "einsum",
                block_tables: Optional[jax.Array] = None,
                layer_loads: bool = False):
    """tokens (B, 1) at absolute position ``pos`` -> (logits (B, 1, V),
    new_cache, metrics).  A per-layer ``dist.placement`` is honored: each
    layer's decode MoE (usually the psum mode) routes through its own
    gate-id table, with shadowed hot experts served locally outside the
    reduction (launch/serve.py wires this for the production decode step).

    ``block_tables`` (B, nb) reads/writes the cache through the paged block
    pool (``init_paged_cache``) instead of per-slot rings.  ``layer_loads=
    True`` additionally returns the (L, E) per-layer expert-load stack as a
    fourth output — the online serve-time replan feed (mirrors
    ``forward(layer_loads=True)``)."""
    dtype = jnp.dtype(cfg.dtype)
    dist, tables = _layer_tables(cfg, dist)
    x = embed_lookup(params["embed"], tokens, dtype)
    cache_len = _cache_len(cfg, cache, block_tables)
    windows = jnp.minimum(B.layer_windows(cfg),
                          jnp.int32(cache_len)) if cache_len else B.layer_windows(cfg)
    n_e = _n_experts(cfg)
    want_loads = layer_loads and cfg.moe is not None

    def body(carry, xs):
        x, metrics = carry
        p_l, window, cache_l = xs[:3]
        l2p = xs[3] if tables is not None else None
        x, new_cache_l, m = B.layer_apply_decode(
            _cast_params(p_l, dtype), cfg, x, cache_l, pos,
            window=window, dist=dist, impl=impl, l2p=l2p,
            block_tables=block_tables)
        metrics = metrics + m if m is not None else metrics
        return ((x.astype(dtype), metrics),
                (new_cache_l, m.load if want_loads else None))

    xs = (params["layers"], windows, cache)
    if tables is not None:
        xs += (tables,)
    (x, metrics), (new_cache, loads) = jax.lax.scan(
        body, (x, MoEMetrics.zero(n_e)), xs)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _logits(params, cfg, x)
    if layer_loads:
        if loads is None:
            loads = jnp.zeros((cfg.num_layers, n_e))
        return logits, new_cache, metrics, loads
    return logits, new_cache, metrics


def _cache_len(cfg: ModelConfig, cache: Any,
               block_tables: Optional[jax.Array] = None) -> int:
    """Ring-buffer length (0 for pure-state caches).  With a paged pool the
    visible length is the gathered per-slot view: table width x block size."""
    if cfg.family == "ssm":
        return 0
    leaf = cache
    if cfg.family == "hybrid":
        leaf = cache["attn"]
    elif cfg.family == "audio":
        leaf = cache["self"]
    if block_tables is not None:
        return block_tables.shape[1] * leaf.positions.shape[-1]
    return leaf.positions.shape[-1]
