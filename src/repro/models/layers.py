"""Shared layer primitives: norms, RoPE, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str) -> dict:
    p = {"scale": jnp.ones((d,))}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,))
    return p


def apply_norm(params: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:  # rmsnorm
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate (..., seq, heads, head_dim) by per-position angles.

    positions: broadcastable to (..., seq) — absolute token positions.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, hd/2) broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_init(rng: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)}


def embed_lookup(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"][tokens].astype(dtype)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in float32 (numerically-sensitive softmax upstream)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


def linear_init(rng: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
                bias: bool = False) -> dict:
    p = {"w": (jax.random.normal(rng, (d_in, d_out)) * d_in ** -0.5).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jax.Array) -> jax.Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y
