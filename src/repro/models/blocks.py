"""Per-layer blocks for every assigned family, with a uniform interface so the
layer stack can be jax.lax.scan'ed (homogeneous params + per-layer window
scalars) and jax.remat'ed.

Families:
  dense / moe / vlm / audio-decoder : [norm -> attn -> norm -> ffn/moe]
  ssm (rwkv6)                       : [norm -> time_mix -> norm -> channel_mix|moe]
  hybrid (hymba)                    : [norm -> (attn || mamba) fused -> norm -> ffn/moe]
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.balance import MoEMetrics
from repro.core.fmoe import DistConfig, _ffn_init, dense_ffn, fmoe_apply, fmoe_init
from repro.models import attention as A
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models.layers import apply_norm, norm_init

FULL_WINDOW = 1 << 30  # "no window" sentinel (larger than any seq len)


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """(L,) per-layer attention window (FULL_WINDOW for global layers)."""
    a = cfg.attention
    L = cfg.num_layers
    if a is None or a.sliding_window is None:
        return jnp.full((L,), FULL_WINDOW, jnp.int32)
    w = jnp.full((L,), a.sliding_window, jnp.int32)
    for g in a.global_layers:
        w = w.at[g].set(FULL_WINDOW)
    return w


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _ffn_block_init(rng: jax.Array, cfg: ModelConfig, dtype) -> dict:
    if cfg.moe is not None:
        return fmoe_init(rng, cfg.d_model, cfg.moe, act=cfg.act,
                         d_ff_dense=cfg.d_ff, dtype=dtype)
    return _ffn_init(rng, 0, cfg.d_model, cfg.d_ff, cfg.act, dtype)


def layer_init(rng: jax.Array, cfg: ModelConfig, *, cross: bool = False) -> dict:
    """One decoder layer.  ``cross=True`` adds cross-attention (whisper dec)."""
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    d = cfg.d_model
    p: dict = {"norm1": norm_init(d, cfg.norm), "norm2": norm_init(d, cfg.norm)}
    if cfg.family == "ssm":
        p["rwkv"] = R.rwkv_init(ks[0], cfg, dtype)
        if cfg.moe is not None:  # fmoefy'd rwkv: MoE replaces channel-mix
            p["ffn"] = _ffn_block_init(ks[1], cfg, dtype)
        return p
    a = cfg.attention
    init_attn = A.mla_init if a.kind == "mla" else A.gqa_init
    p["attn"] = init_attn(ks[0], d, a, dtype)
    if cfg.family == "hybrid":
        p["mamba"] = M.mamba_init(ks[1], d, cfg.ssm, dtype)
        p["norm_a"] = norm_init(d, cfg.norm)
        p["norm_m"] = norm_init(d, cfg.norm)
    if cross:
        p["norm_cross"] = norm_init(d, cfg.norm)
        p["cross_attn"] = A.gqa_init(ks[2], d, a, dtype)
    p["ffn"] = _ffn_block_init(ks[3], cfg, dtype)
    return p


# ---------------------------------------------------------------------------
# FFN / mixer application
# ---------------------------------------------------------------------------


def _apply_ffn(p: dict, cfg: ModelConfig, x: jax.Array,
               dist: Optional[DistConfig], impl: str = "einsum", l2p=None,
               rng=None):
    """``l2p``: this layer's logical->physical gate-id table, scanned out of
    a stacked per-layer placement by models/lm.py (None = shared/no plan).
    ``rng``: optional per-layer gate key (exploration routers: noisy_topk /
    gumbel); None keeps every router deterministic."""
    if cfg.moe is not None:
        return fmoe_apply(p, x, cfg.moe, act=cfg.act, dist=dist, impl=impl,
                          l2p=l2p, rng=rng)
    return dense_ffn(p, x, cfg.act), None


def _mixer_seq(p: dict, cfg: ModelConfig, x: jax.Array, window,
               state: Optional[Any]):
    """Token mixer over a full sequence.  Returns (y, new_state)."""
    if cfg.family == "ssm":
        return R.time_mix(p["rwkv"], x, state, cfg)
    a = cfg.attention
    if cfg.family == "hybrid":
        y_a = A.gqa_apply(p["attn"], x, a, window=window)
        y_m, mstate = M.mamba_apply(p["mamba"], x, state, cfg.ssm)
        y = 0.5 * (apply_norm(p["norm_a"], y_a, cfg.norm)
                   + apply_norm(p["norm_m"], y_m, cfg.norm))
        return y, mstate
    if a.kind == "mla":
        return A.mla_apply(p["attn"], x, a, window=window), None
    return A.gqa_apply(p["attn"], x, a, window=window), None


def _mixer_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache, pos, window,
                  block_tables=None):
    if cfg.family == "ssm":
        # single-step time-mix via the seq path with S=1 and the cached shift
        y, new_state = R.time_mix(p["rwkv"], x, cache, cfg)
        return y, new_state
    a = cfg.attention
    if cfg.family == "hybrid":
        y_a, kv = A.gqa_decode(p["attn"], x, cache["attn"], pos, a, window=window)
        y_m, ms = M.mamba_apply(p["mamba"], x, cache["mamba"], cfg.ssm)
        y = 0.5 * (apply_norm(p["norm_a"], y_a, cfg.norm)
                   + apply_norm(p["norm_m"], y_m, cfg.norm))
        return y, {"attn": kv, "mamba": ms}
    if block_tables is not None:  # paged/blocked pool (continuous batching)
        if a.kind == "mla":
            return A.mla_decode_paged(p["attn"], x, cache, block_tables, pos,
                                      a, window=window)
        return A.gqa_decode_paged(p["attn"], x, cache, block_tables, pos,
                                  a, window=window)
    if a.kind == "mla":
        return A.mla_decode(p["attn"], x, cache, pos, a, window=window)
    return A.gqa_decode(p["attn"], x, cache, pos, a, window=window)


# ---------------------------------------------------------------------------
# Full-sequence layer (train / prefill)
# ---------------------------------------------------------------------------


def _constrain_attn_batch(x: jax.Array, dist: Optional[DistConfig]):
    """§Perf: when attention weights are replicated over the model axis
    (head-count not divisible), shard the attention *batch* over every mesh
    axis instead — scores shrink by the model-axis size for the price of two
    small activation reshards."""
    if dist is None or not dist.constrain_tokens:
        return x
    total = 1
    for a in dist.token_axes:
        total *= dist.mesh.shape[a]
    if not dist.token_axes or x.shape[0] % total:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(dist.mesh, P(dist.token_axes, None, None)))


def layer_apply_seq(p: dict, cfg: ModelConfig, x: jax.Array, *, window,
                    dist: Optional[DistConfig] = None,
                    enc_out: Optional[jax.Array] = None,
                    mixer_state: Optional[Any] = None,
                    impl: str = "einsum", l2p=None, rng=None):
    """x (B, S, d) -> (x, MoEMetrics|None).  mixer_state: SSM initial state
    (zeros created by the caller for ssm/hybrid families)."""
    xn = apply_norm(p["norm1"], x, cfg.norm)
    if cfg.family not in ("ssm", "hybrid"):
        xn = _constrain_attn_batch(xn, dist)
    h, _ = _mixer_seq(p, cfg, xn, window, mixer_state)
    x = x + h
    if enc_out is not None:  # whisper decoder cross-attention
        h = A.gqa_apply(p["cross_attn"], apply_norm(p["norm_cross"], x, cfg.norm),
                        cfg.attention, window=FULL_WINDOW, kv_x=enc_out,
                        causal=False)
        x = x + h
    if cfg.family == "ssm" and cfg.moe is None:
        h, _ = R.channel_mix(p["rwkv"], apply_norm(p["norm2"], x, cfg.norm),
                             mixer_state)
        metrics = None
    else:
        h, metrics = _apply_ffn(p.get("ffn"), cfg, apply_norm(p["norm2"], x, cfg.norm), dist,
                                impl, l2p, rng)
    return x + h, metrics


# ---------------------------------------------------------------------------
# Prefill layer: full-sequence forward that also populates the decode cache
# ---------------------------------------------------------------------------


def layer_apply_prefill(p: dict, cfg: ModelConfig, x: jax.Array, cache, *,
                        window, dist: Optional[DistConfig] = None,
                        start: int = 0, impl: str = "einsum", l2p=None):
    """x (B, S, d), per-layer cache -> (x, filled_cache, MoEMetrics|None).

    One full-sequence pass writes every position's K/V (or recurrent state)
    into the cache so decoding can continue at position S — O(1) model
    passes for the prompt instead of S decode steps."""
    xn = apply_norm(p["norm1"], x, cfg.norm)
    a = cfg.attention

    if cfg.family == "ssm":
        h, c1 = R.time_mix(p["rwkv"], xn, cache, cfg)
        x = x + h
        xn2 = apply_norm(p["norm2"], x, cfg.norm)
        if cfg.moe is None:
            h, c2 = R.channel_mix(p["rwkv"], xn2, c1)
            return x + h, c2, None
        h, metrics = _apply_ffn(p["ffn"], cfg, xn2, dist, impl, l2p)
        return x + h, c1, metrics

    if cfg.family == "hybrid":
        y_a, (k, v) = A.gqa_apply(p["attn"], xn, a, window=window,
                                  return_kv=True)
        kv = A.fill_kv_cache(cache["attn"], k, v, start=start)
        y_m, ms = M.mamba_apply(p["mamba"], xn, cache["mamba"], cfg.ssm)
        h = 0.5 * (apply_norm(p["norm_a"], y_a, cfg.norm)
                   + apply_norm(p["norm_m"], y_m, cfg.norm))
        x = x + h
        new_cache = {"attn": kv, "mamba": ms}
    elif cfg.family == "audio":
        h, (k, v) = A.gqa_apply(p["attn"], xn, a, window=window,
                                return_kv=True)
        x = x + h
        q = apply_norm(p["norm_cross"], x, cfg.norm)
        h = A.gqa_apply(p["cross_attn"], q, a, window=FULL_WINDOW,
                        kv_x=cache["enc_out"], causal=False)
        x = x + h
        new_cache = {"self": A.fill_kv_cache(cache["self"], k, v, start=start),
                     "enc_out": cache["enc_out"]}
    elif a.kind == "mla":
        h, (ckv, kr) = A.mla_apply(p["attn"], xn, a, window=window,
                                   return_kv=True)
        x = x + h
        new_cache = A.fill_mla_cache(cache, ckv, kr, start=start)
    else:
        h, (k, v) = A.gqa_apply(p["attn"], xn, a, window=window,
                                return_kv=True)
        x = x + h
        new_cache = A.fill_kv_cache(cache, k, v, start=start)

    h, metrics = _apply_ffn(p["ffn"], cfg, apply_norm(p["norm2"], x, cfg.norm),
                            dist, impl, l2p)
    return x + h, new_cache, metrics


# ---------------------------------------------------------------------------
# One-token decode layer
# ---------------------------------------------------------------------------


def layer_apply_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache, pos, *,
                       window, dist: Optional[DistConfig] = None,
                       impl: str = "einsum", l2p=None, block_tables=None):
    """x (B, 1, d), per-layer cache -> (x, new_cache, MoEMetrics|None).

    ``block_tables`` (B, nb) switches the attention cache to the paged block
    pool (models/attention paged decode) — plain attention families only;
    recurrent-state caches (ssm/hybrid) and the audio enc-out dict keep the
    contiguous per-slot layout."""
    if block_tables is not None and cfg.family in ("ssm", "hybrid", "audio"):
        raise NotImplementedError(
            f"paged KV cache is not supported for family {cfg.family!r}")
    if cfg.family == "ssm":
        h, c1 = R.time_mix(p["rwkv"], apply_norm(p["norm1"], x, cfg.norm), cache, cfg)
        x = x + h
        if cfg.moe is None:
            h, c2 = R.channel_mix(p["rwkv"], apply_norm(p["norm2"], x, cfg.norm), c1)
            return x + h, c2, None
        h, metrics = _apply_ffn(p["ffn"], cfg, apply_norm(p["norm2"], x, cfg.norm), dist,
                                impl, l2p)
        return x + h, c1, metrics

    attn_cache = cache["attn"] if isinstance(cache, dict) and "attn" in cache \
        and cfg.family != "hybrid" else cache
    if cfg.family == "audio":
        h, kv = A.gqa_decode(p["attn"], apply_norm(p["norm1"], x, cfg.norm),
                             cache["self"], pos, cfg.attention, window=window)
        x = x + h
        # cross attention against precomputed encoder K/V
        q = apply_norm(p["norm_cross"], x, cfg.norm)
        h = A.gqa_apply(p["cross_attn"], q, cfg.attention, window=FULL_WINDOW,
                        kv_x=cache["enc_out"], causal=False)
        x = x + h
        h, metrics = _apply_ffn(p["ffn"], cfg, apply_norm(p["norm2"], x, cfg.norm), dist,
                                impl, l2p)
        return x + h, {"self": kv, "enc_out": cache["enc_out"]}, metrics

    h, new_cache = _mixer_decode(p, cfg, apply_norm(p["norm1"], x, cfg.norm),
                                 attn_cache, pos, window,
                                 block_tables=block_tables)
    x = x + h
    h, metrics = _apply_ffn(p["ffn"], cfg, apply_norm(p["norm2"], x, cfg.norm), dist,
                            impl, l2p)
    return x + h, new_cache, metrics


# ---------------------------------------------------------------------------
# Per-layer cache/state construction
# ---------------------------------------------------------------------------


def layer_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                enc_out: Optional[jax.Array] = None):
    a = cfg.attention
    if cfg.family == "ssm":
        return R.rwkv_init_state(batch, cfg, dtype)
    if cfg.family == "hybrid":
        return {"attn": A.gqa_init_cache(batch, cache_len, a, dtype),
                "mamba": M.mamba_init_state(batch, cfg.d_model, cfg.ssm, dtype)}
    if cfg.family == "audio":
        return {"self": A.gqa_init_cache(batch, cache_len, a, dtype),
                "enc_out": enc_out if enc_out is not None else jnp.zeros(
                    (batch, cfg.encoder.num_frames, cfg.d_model), dtype)}
    if a is not None and a.kind == "mla":
        return A.mla_init_cache(batch, cache_len, a, dtype)
    return A.gqa_init_cache(batch, cache_len, a, dtype)


def layer_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                      dtype):
    """Per-layer paged block pool (plain attention families only)."""
    a = cfg.attention
    if cfg.family in ("ssm", "hybrid", "audio") or a is None:
        raise NotImplementedError(
            f"paged KV cache is not supported for family {cfg.family!r}")
    if a.kind == "mla":
        return A.mla_init_paged(num_blocks, block_size, a, dtype)
    return A.gqa_init_paged(num_blocks, block_size, a, dtype)


def mixer_state(cfg: ModelConfig, batch: int, dtype):
    """Zero SSM state for full-sequence processing (ssm / hybrid)."""
    if cfg.family == "ssm":
        return R.rwkv_init_state(batch, cfg, dtype)
    if cfg.family == "hybrid":
        return M.mamba_init_state(batch, cfg.d_model, cfg.ssm, dtype)
    return None
