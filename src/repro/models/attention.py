"""Attention blocks: GQA (llama/qwen/starcoder-style) and MLA (DeepSeek-V2).

Full-sequence paths use a blockwise online-softmax ("flash"-pattern) scan over
KV chunks so the (S, S) score matrix is never materialized — required for the
prefill_32k shape where a dense 32k x 32k x heads score tensor would exceed
HBM.  Sliding windows are traced per-layer scalars so a scanned layer stack
can mix windowed and global layers (Hymba).

Decode paths run one query against a ring-buffer KV cache (absolute positions
stored alongside so RoPE is applied at write time and window/causal masks are
position-exact).  MLA decode uses the *absorbed* form: only the compressed
latent (kv_lora + rope_k) is cached and W_uk/W_uv are folded into the query /
output projections — the memory advantage that motivates MLA.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import apply_rope, linear, linear_init

_NEG = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash-pattern) multi-head attention
# ---------------------------------------------------------------------------


# KV-chunk size for the online-softmax scan.  Overridable (e.g. the roofline
# layer-probe sets it to the full sequence so no inner while-loop hides
# attention FLOPs from XLA's trip-count-blind cost analysis).
DEFAULT_CHUNK = 1024
_CHUNK_OVERRIDE: list = [None]  # set via chunk_override() during tracing
# score dtype for the blockwise scan: f32 (default) or bf16 (§Perf —
# halves the dominant HBM term; m/l softmax stats stay f32)
SCORE_DTYPE: list = [jnp.float32]


def chunk_override(value):
    """Context manager: force the KV-chunk size while tracing/lowering."""
    return _list_override(_CHUNK_OVERRIDE, value)


def score_dtype(value):
    """Context manager: set the blockwise-attention score dtype (§Perf)."""
    return _list_override(SCORE_DTYPE, value)


def _list_override(cell, value):
    import contextlib

    @contextlib.contextmanager
    def _cm():
        old = cell[0]
        cell[0] = value
        try:
            yield
        finally:
            cell[0] = old
    return _cm()


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: jax.Array | int, q_offset: int = 0,
                        chunk: int | None = None, causal: bool = True) -> jax.Array:
    """softmax(q k^T) v with online softmax over KV chunks.

    q: (B, Sq, H, dk); k: (B, Skv, KV, dk); v: (B, Skv, KV, dv).
    window: scalar — attend only to keys with 0 <= i - j < window (i absolute
    query pos = q_offset + row).  Pass Skv (or larger) for full attention.
    """
    B, Sq, H, dk = q.shape
    Skv_real, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    chunk = min(chunk or _CHUNK_OVERRIDE[0] or DEFAULT_CHUNK, Skv_real)
    n_pad = (-Skv_real) % chunk
    if n_pad:  # pad keys to a chunk multiple; padded slots masked out below
        pad = [(0, 0), (0, n_pad), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    Skv = Skv_real + n_pad
    n_chunks = Skv // chunk

    sdt = SCORE_DTYPE[0]
    neg = _NEG if sdt == jnp.float32 else -3e38  # bf16 max ~3.39e38
    qg = q.reshape(B, Sq, KV, G, dk).astype(sdt)
    scale = dk ** -0.5
    i_pos = q_offset + jnp.arange(Sq)  # absolute query positions
    window = jnp.asarray(window, jnp.int32)

    kc = k.reshape(B, n_chunks, chunk, KV, dk)
    vc = v.reshape(B, n_chunks, chunk, KV, dv)

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j0 = inp
        s = jnp.einsum("bskgd,bckd->bskgc", qg, kj.astype(sdt)) * sdt(scale)
        j_pos = j0 + jnp.arange(chunk)
        dist = i_pos[:, None] - j_pos[None, :]  # (Sq, chunk)
        mask = (dist < window) & (j_pos < Skv_real)[None, :]
        if causal:
            mask &= (dist >= 0)
        s = jnp.where(mask[None, :, None, None, :], s, sdt(neg))
        m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
        p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(sdt)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, dtype=jnp.float32)
        acc = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p, vj.astype(sdt),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, KV, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, dv), jnp.float32)
    js = jnp.arange(n_chunks) * chunk
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), js))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_positions: jax.Array, pos: jax.Array,
                     window: jax.Array | int) -> jax.Array:
    """One-token attention against a ring-buffer cache.

    q: (B, 1, H, dk); caches (B, W, KV, d*); kv_positions (B, W) absolute
    positions of cached entries (-1 = empty); pos: scalar or (B, 1)
    per-sequence current positions.
    """
    B, _, H, dk = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dk).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k_cache.astype(jnp.float32)) * dk ** -0.5
    dist = pos - kv_positions  # (B, W)
    valid = (kv_positions >= 0) & (dist >= 0) & (dist < jnp.asarray(window, jnp.int32))
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, W, KV, dk)
    v: jax.Array  # (B, W, KV, dv)
    positions: jax.Array  # (B, W) absolute positions, -1 empty


def gqa_init(rng: jax.Array, d_model: int, cfg: AttentionConfig, dtype) -> dict:
    ks = jax.random.split(rng, 4)
    return {
        "wq": linear_init(ks[0], d_model, cfg.num_heads * cfg.head_dim, dtype, cfg.qkv_bias),
        "wk": linear_init(ks[1], d_model, cfg.num_kv_heads * cfg.head_dim, dtype, cfg.qkv_bias),
        "wv": linear_init(ks[2], d_model, cfg.num_kv_heads * cfg.head_dim, dtype, cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.num_heads * cfg.head_dim, d_model, dtype),
    }


def gqa_apply(params: dict, x: jax.Array, cfg: AttentionConfig, *,
              window: jax.Array | int, positions: Optional[jax.Array] = None,
              kv_x: Optional[jax.Array] = None, causal: bool = True,
              return_kv: bool = False):
    """Full-sequence GQA.  kv_x (cross-attention source) defaults to x.
    ``return_kv`` additionally returns the (post-RoPE) k, v for prefill
    cache population."""
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    q = linear(params["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = linear(params["wk"], src).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = linear(params["wv"], src).reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    if causal:  # self-attention: rotate q and k
        pos = jnp.arange(S) if positions is None else positions
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, jnp.arange(Skv), cfg.rope_theta)
    out = blockwise_attention(q, k, v, window=window, causal=causal)
    y = linear(params["wo"], out.reshape(B, S, -1))
    if return_kv:
        return y, (k, v)
    return y


def _per_seq_pos(pos: jax.Array, B: int) -> jax.Array:
    """Normalize pos to (B,): scalars broadcast (continuous batching passes a
    per-sequence position vector)."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos


def gqa_decode(params: dict, x: jax.Array, cache: KVCache, pos: jax.Array,
               cfg: AttentionConfig, *, window: jax.Array | int) -> tuple:
    """One-token decode; writes (k, v, pos) into each sequence's ring slot
    pos[b] % W.  ``pos``: scalar or (B,) per-sequence positions."""
    B, _, _ = x.shape
    W = cache.k.shape[1]
    posb = _per_seq_pos(pos, B)  # (B,)
    q = linear(params["wq"], x).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k = linear(params["wk"], x).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v = linear(params["wv"], x).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, posb[:, None], cfg.rope_theta)
    k = apply_rope(k, posb[:, None], cfg.rope_theta)
    slots = posb % W
    bidx = jnp.arange(B)
    new_cache = KVCache(
        cache.k.at[bidx, slots].set(k[:, 0].astype(cache.k.dtype)),
        cache.v.at[bidx, slots].set(v[:, 0].astype(cache.v.dtype)),
        cache.positions.at[bidx, slots].set(posb),
    )
    out = decode_attention(q, new_cache.k, new_cache.v, new_cache.positions,
                           posb[:, None], window)
    return linear(params["wo"], out.reshape(B, 1, -1)), new_cache


def fill_kv_cache(cache: KVCache, k: jax.Array, v: jax.Array, *,
                  start: int = 0) -> KVCache:
    """Prefill: write S (post-RoPE) rows into the ring starting at absolute
    position ``start``; only the last W survive if S exceeds the ring."""
    B, S = k.shape[:2]
    W = cache.k.shape[1]
    tail = max(0, S - W)
    pos_abs = start + jnp.arange(tail, S)
    slots = pos_abs % W
    return KVCache(
        cache.k.at[:, slots].set(k[:, tail:].astype(cache.k.dtype)),
        cache.v.at[:, slots].set(v[:, tail:].astype(cache.v.dtype)),
        cache.positions.at[:, slots].set(
            jnp.broadcast_to(pos_abs, (B, S - tail)).astype(jnp.int32)),
    )


def fill_mla_cache(cache: MLACache, ckv: jax.Array, kr: jax.Array, *,
                   start: int = 0) -> MLACache:
    """Prefill the compressed-latent cache (ckv (B,S,lora), kr (B,S,rope))."""
    B, S = ckv.shape[:2]
    W = cache.ckv.shape[1]
    tail = max(0, S - W)
    pos_abs = start + jnp.arange(tail, S)
    slots = pos_abs % W
    return MLACache(
        cache.ckv.at[:, slots].set(ckv[:, tail:].astype(cache.ckv.dtype)),
        cache.kr.at[:, slots].set(kr[:, tail:].astype(cache.kr.dtype)),
        cache.positions.at[:, slots].set(
            jnp.broadcast_to(pos_abs, (B, S - tail)).astype(jnp.int32)),
    )


def gqa_init_cache(batch: int, max_len: int, cfg: AttentionConfig, dtype) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.full((batch, max_len), -1, jnp.int32))


# ---------------------------------------------------------------------------
# Paged (blocked) KV cache — continuous-batching serving
# ---------------------------------------------------------------------------
#
# The pool replaces the per-slot ring with shared physical blocks of
# ``block_size`` rows; each decode slot owns a block *table* mapping its
# logical block j (positions [j*bs, (j+1)*bs)) to a pool row, so
# heterogeneous sequence lengths never fragment a contiguous ring.  Entry
# order inside the gathered per-slot view equals the absolute position
# ((p // bs) * bs + p % bs == p), and unallocated/stale entries carry
# position -1, so decode_attention masks them to an exact-zero softmax
# weight — the paged read is **bitwise identical** to a ring cache of length
# blocks_per_slot * block_size (tests/test_scheduler.py locks this).
#
# Pool row 0 is the permanent null block (never written; -1 positions) that
# unallocated table entries point at; row 1 is the scratch block that
# absorbs writes from inactive slots (table rows all-null), so a fixed-width
# decode batch can tick with empty slots without corrupting shared state.

NULL_BLOCK = 0
SCRATCH_BLOCK = 1
RESERVED_BLOCKS = 2


class PagedKVCache(NamedTuple):
    k: jax.Array  # (P, bs, KV, dk) shared block pool
    v: jax.Array  # (P, bs, KV, dv)
    positions: jax.Array  # (P, bs) absolute positions, -1 empty


class PagedMLACache(NamedTuple):
    ckv: jax.Array  # (P, bs, kv_lora)
    kr: jax.Array  # (P, bs, qk_rope)
    positions: jax.Array  # (P, bs)


def gqa_init_paged(num_blocks: int, block_size: int, cfg: AttentionConfig,
                   dtype) -> PagedKVCache:
    shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.full((num_blocks, block_size), -1, jnp.int32))


def mla_init_paged(num_blocks: int, block_size: int, cfg: AttentionConfig,
                   dtype) -> PagedMLACache:
    return PagedMLACache(
        jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), dtype),
        jnp.zeros((num_blocks, block_size, cfg.qk_rope_head_dim), dtype),
        jnp.full((num_blocks, block_size), -1, jnp.int32))


def _paged_target(tables: jax.Array, posb: jax.Array, bs: int):
    """(pb, off): write target per slot.  Null-block entries (inactive or
    out-of-table positions) redirect to the scratch block."""
    nb = tables.shape[1]
    blk = jnp.clip(posb // bs, 0, nb - 1)
    pb = tables[jnp.arange(posb.shape[0]), blk]
    pb = jnp.where(pb == NULL_BLOCK, SCRATCH_BLOCK, pb)
    return pb, posb % bs


def _paged_view(pool_leaf: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather a per-slot contiguous view: (P, bs, ...) x (B, nb) ->
    (B, nb*bs, ...).  Entry index == absolute position."""
    g = jnp.take(pool_leaf, tables, axis=0)  # (B, nb, bs, ...)
    B, nb, bs = g.shape[:3]
    return g.reshape(B, nb * bs, *g.shape[3:])


def gqa_decode_paged(params: dict, x: jax.Array, cache: PagedKVCache,
                     tables: jax.Array, pos: jax.Array, cfg: AttentionConfig,
                     *, window: jax.Array | int) -> tuple:
    """One-token decode against the shared block pool.  ``tables`` (B, nb)
    int32 maps each slot's logical blocks to pool rows (0 = unallocated)."""
    B = x.shape[0]
    bs = cache.k.shape[1]
    posb = _per_seq_pos(pos, B)
    q = linear(params["wq"], x).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k = linear(params["wk"], x).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v = linear(params["wv"], x).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, posb[:, None], cfg.rope_theta)
    k = apply_rope(k, posb[:, None], cfg.rope_theta)
    pb, off = _paged_target(tables, posb, bs)
    new_cache = PagedKVCache(
        cache.k.at[pb, off].set(k[:, 0].astype(cache.k.dtype)),
        cache.v.at[pb, off].set(v[:, 0].astype(cache.v.dtype)),
        cache.positions.at[pb, off].set(posb),
    )
    out = decode_attention(q, _paged_view(new_cache.k, tables),
                           _paged_view(new_cache.v, tables),
                           _paged_view(new_cache.positions, tables),
                           posb[:, None], window)
    return linear(params["wo"], out.reshape(B, 1, -1)), new_cache


def mla_decode_paged(params: dict, x: jax.Array, cache: PagedMLACache,
                     tables: jax.Array, pos: jax.Array, cfg: AttentionConfig,
                     *, window: jax.Array | int) -> tuple:
    """Absorbed-form MLA decode against the shared latent block pool."""
    B = x.shape[0]
    H = cfg.num_heads
    bs = cache.ckv.shape[1]
    posb = _per_seq_pos(pos, B)
    q_nope, q_rope = _mla_q(params, x, cfg)
    q_rope = apply_rope(q_rope, posb[:, None], cfg.rope_theta)

    ckv = linear(params["w_dkv"], x)[:, 0]  # (B, lora)
    kr = linear(params["w_kr"], x).reshape(B, 1, 1, cfg.qk_rope_head_dim)
    kr = apply_rope(kr, posb[:, None], cfg.rope_theta)[:, 0, 0]

    pb, off = _paged_target(tables, posb, bs)
    new_cache = PagedMLACache(
        cache.ckv.at[pb, off].set(ckv.astype(cache.ckv.dtype)),
        cache.kr.at[pb, off].set(kr.astype(cache.kr.dtype)),
        cache.positions.at[pb, off].set(posb),
    )
    ckv_v = _paged_view(new_cache.ckv, tables)  # (B, nb*bs, lora)
    kr_v = _paged_view(new_cache.kr, tables)
    pos_v = _paged_view(new_cache.positions, tables)

    q_eff = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0].astype(jnp.float32),
                       params["w_uk"].astype(jnp.float32))
    s = jnp.einsum("bhr,bwr->bhw", q_eff, ckv_v.astype(jnp.float32))
    s += jnp.einsum("bhd,bwd->bhw", q_rope[:, 0].astype(jnp.float32),
                    kr_v.astype(jnp.float32))
    s *= (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    dist = posb[:, None] - pos_v
    valid = (pos_v >= 0) & (dist >= 0) & (dist < jnp.asarray(window, jnp.int32))
    s = jnp.where(valid[:, None, :], s, _NEG)
    p = jax.nn.softmax(s, -1)
    o_lat = jnp.einsum("bhw,bwr->bhr", p, ckv_v.astype(jnp.float32))
    out = jnp.einsum("bhr,hrd->bhd", o_lat, params["w_uv"].astype(jnp.float32))
    out = out.reshape(B, 1, H * cfg.v_head_dim).astype(x.dtype)
    return linear(params["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    ckv: jax.Array  # (B, W, kv_lora) compressed latent
    kr: jax.Array  # (B, W, qk_rope) decoupled rope key (shared across heads)
    positions: jax.Array  # (B, W)


def mla_init(rng: jax.Array, d_model: int, cfg: AttentionConfig, dtype) -> dict:
    ks = jax.random.split(rng, 7)
    H = cfg.num_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "w_dkv": linear_init(ks[0], d_model, cfg.kv_lora_rank, dtype),
        "w_kr": linear_init(ks[1], d_model, cfg.qk_rope_head_dim, dtype),
        # per-head up-projections from the latent
        "w_uk": (jax.random.normal(ks[2], (H, cfg.kv_lora_rank, cfg.qk_nope_head_dim))
                 * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (H, cfg.kv_lora_rank, cfg.v_head_dim))
                 * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "wo": linear_init(ks[4], H * cfg.v_head_dim, d_model, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = linear_init(ks[5], d_model, cfg.q_lora_rank, dtype)
        p["w_uq"] = linear_init(ks[6], cfg.q_lora_rank, H * qd, dtype)
    else:
        p["w_q"] = linear_init(ks[5], d_model, H * qd, dtype)
    return p


def _mla_q(params: dict, x: jax.Array, cfg: AttentionConfig):
    B, S, _ = x.shape
    H = cfg.num_heads
    if "w_dq" in params:
        q = linear(params["w_uq"], linear(params["w_dq"], x))
    else:
        q = linear(params["w_q"], x)
    q = q.reshape(B, S, H, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    return jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)  # q_nope, q_rope


def mla_apply(params: dict, x: jax.Array, cfg: AttentionConfig, *,
              window: jax.Array | int,
              positions: Optional[jax.Array] = None,
              return_kv: bool = False):
    """Training/prefill MLA: materialize per-head K/V from the latent and run
    blockwise attention on concat(nope, rope) keys.  ``return_kv`` returns
    the compressed (ckv, kr) latents for prefill cache population."""
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, cfg)
    pos = jnp.arange(S) if positions is None else positions
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    ckv = linear(params["w_dkv"], x)  # (B, S, lora)
    kr = linear(params["w_kr"], x).reshape(B, S, 1, cfg.qk_rope_head_dim)
    kr = apply_rope(kr, jnp.arange(S), cfg.rope_theta)
    k_nope = jnp.einsum("bsr,hrd->bshd", ckv, params["w_uk"])
    v = jnp.einsum("bsr,hrd->bshd", ckv, params["w_uv"])

    q = jnp.concatenate([q_nope, q_rope], -1)  # (B,S,H,nope+rope)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr, (B, S, H, cfg.qk_rope_head_dim))], -1)
    out = blockwise_attention(q, k, v, window=window)
    y = linear(params["wo"], out.reshape(B, S, -1))
    if return_kv:
        return y, (ckv, kr[:, :, 0, :])
    return y


def mla_decode(params: dict, x: jax.Array, cache: MLACache, pos: jax.Array,
               cfg: AttentionConfig, *, window: jax.Array | int) -> tuple:
    """Absorbed-form decode: score latents directly, cache only (ckv, kr).
    ``pos``: scalar or (B,) per-sequence positions."""
    B = x.shape[0]
    H = cfg.num_heads
    W = cache.ckv.shape[1]
    posb = _per_seq_pos(pos, B)
    q_nope, q_rope = _mla_q(params, x, cfg)  # (B,1,H,*)
    q_rope = apply_rope(q_rope, posb[:, None], cfg.rope_theta)

    ckv = linear(params["w_dkv"], x)[:, 0]  # (B, lora)
    kr = linear(params["w_kr"], x).reshape(B, 1, 1, cfg.qk_rope_head_dim)
    kr = apply_rope(kr, posb[:, None], cfg.rope_theta)[:, 0, 0]  # (B, rope)

    slots = posb % W
    bidx = jnp.arange(B)
    cache = MLACache(cache.ckv.at[bidx, slots].set(ckv.astype(cache.ckv.dtype)),
                     cache.kr.at[bidx, slots].set(kr.astype(cache.kr.dtype)),
                     cache.positions.at[bidx, slots].set(posb))

    # absorb W_uk into q: q_eff (B,H,lora) scores against cached latents
    q_eff = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0].astype(jnp.float32),
                       params["w_uk"].astype(jnp.float32))
    s = jnp.einsum("bhr,bwr->bhw", q_eff, cache.ckv.astype(jnp.float32))
    s += jnp.einsum("bhd,bwd->bhw", q_rope[:, 0].astype(jnp.float32),
                    cache.kr.astype(jnp.float32))
    s *= (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    dist = posb[:, None] - cache.positions
    valid = (cache.positions >= 0) & (dist >= 0) & (dist < jnp.asarray(window, jnp.int32))
    s = jnp.where(valid[:, None, :], s, _NEG)
    p = jax.nn.softmax(s, -1)
    o_lat = jnp.einsum("bhw,bwr->bhr", p, cache.ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,hrd->bhd", o_lat, params["w_uv"].astype(jnp.float32))
    out = out.reshape(B, 1, H * cfg.v_head_dim).astype(x.dtype)
    return linear(params["wo"], out), cache


def mla_init_cache(batch: int, max_len: int, cfg: AttentionConfig, dtype) -> MLACache:
    return MLACache(jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
                    jnp.full((batch, max_len), -1, jnp.int32))
