"""Mamba selective SSM head (used by Hymba's parallel attn+mamba layers).

h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * u_t ;  y_t = C_t . h_t + D * u_t
with input-dependent (selective) B, C, dt.  lax.scan over time for sequences,
single state update for decode.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import linear_init


class MambaState(NamedTuple):
    h: jax.Array  # (B, d_in, N) ssm state
    conv: jax.Array  # (B, conv_width - 1, d_in) causal-conv tail


def mamba_init(rng: jax.Array, d_model: int, cfg: SSMConfig, dtype) -> dict:
    d_in = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, (d_model + 15) // 16)
    ks = jax.random.split(rng, 6)
    A = jnp.tile(jnp.arange(1, cfg.state_size + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_proj": linear_init(ks[0], d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, d_in)) *
                   cfg.conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": linear_init(ks[2], d_in, dt_rank + 2 * cfg.state_size, dtype),
        "dt_proj": linear_init(ks[3], dt_rank, d_in, dtype, bias=True),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,)),
        "out_proj": linear_init(ks[4], d_in, d_model, dtype),
    }


def _split_xproj(p: dict, xc: jax.Array, cfg: SSMConfig, dt_rank: int):
    proj = xc @ p["x_proj"]["w"]
    dt_low, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + cfg.state_size], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"]["w"] + p["dt_proj"]["b"])
    return dt, Bc, Cc


def mamba_apply(p: dict, x: jax.Array, state: MambaState, cfg: SSMConfig):
    """x (B, S, d_model) -> (y, new_state)."""
    B, S, d_model = x.shape
    d_in = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, (d_model + 15) // 16)

    xz = x @ p["in_proj"]["w"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_in) each

    # causal depthwise conv over time, seeded by cached tail
    pad = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    cw = cfg.conv_width
    xc = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(cw)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt, Bc, Cc = _split_xproj(p, xc, cfg, dt_rank)
    A = -jnp.exp(p["A_log"])  # (d_in, N)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,d_in), (B,d_in), (B,N), (B,N)
        dA = jnp.exp(dtt[..., None].astype(jnp.float32) * A)  # (B, d_in, N)
        dBx = (dtt * xt)[..., None].astype(jnp.float32) * Bt[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32))
        return h, y

    h_fin, ys = jax.lax.scan(
        step, state.h,
        (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
         Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2).astype(x.dtype) + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]["w"]
    new_conv = pad[:, -(cw - 1):] if cw > 1 else state.conv
    return out, MambaState(h_fin, new_conv.astype(state.conv.dtype))


def mamba_init_state(batch: int, d_model: int, cfg: SSMConfig,
                     dtype=jnp.float32) -> MambaState:
    d_in = cfg.expand * d_model
    return MambaState(jnp.zeros((batch, d_in, cfg.state_size), jnp.float32),
                      jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype))
