"""RWKV6 "Finch" time-mix + channel-mix (arXiv:2404.05892).

Attention-free linear recurrence with *data-dependent* per-channel decay
(the Finch contribution): w_t = exp(-exp(w0 + lora(x_t))), state
S_t = diag(w_t) S_{t-1} + k_t v_t^T per 64-wide head.  Sequence processing is
a lax.scan over time; decode is a single state update — O(1) memory in
sequence length, which is why rwkv6-7b runs long_500k natively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import linear_init

TSHIFT_RANK = 32
_MIX = ("r", "k", "v", "w", "g")


class RWKVState(NamedTuple):
    S: jax.Array  # (B, n_heads, dk, dv) wkv state
    sx_tm: jax.Array  # (B, d) previous token (time-mix shift)
    sx_cm: jax.Array  # (B, d) previous token (channel-mix shift)


def rwkv_init(rng: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    r = cfg.ssm.lora_rank
    ks = jax.random.split(rng, 12)
    n01 = lambda k, shape, s: (jax.random.normal(k, shape) * s).astype(dtype)
    return {
        # ddlerp token-shift mixers
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),
        "ts_w1": n01(ks[0], (d, 5 * TSHIFT_RANK), d ** -0.5),
        "ts_w2": n01(ks[1], (5, TSHIFT_RANK, d), TSHIFT_RANK ** -0.5),
        # projections
        "wr": linear_init(ks[2], d, d, dtype),
        "wk": linear_init(ks[3], d, d, dtype),
        "wv": linear_init(ks[4], d, d, dtype),
        "wg": linear_init(ks[5], d, d, dtype),
        "wo": linear_init(ks[6], d, d, dtype),
        # data-dependent decay (Finch)
        "w0": jnp.full((d,), -6.0, dtype),
        "decay_w1": n01(ks[7], (d, r), d ** -0.5),
        "decay_w2": n01(ks[8], (r, d), r ** -0.5),
        "u": n01(ks[9], (d,), 0.5),  # per-channel bonus ("first")
        "ln_x_scale": jnp.ones((d,), dtype),  # per-head group norm
        # channel mix
        "mu_ck": jnp.zeros((d,), dtype),
        "mu_cr": jnp.zeros((d,), dtype),
        "cm_k": linear_init(ks[10], d, cfg.d_ff, dtype),
        "cm_v": linear_init(ks[11], cfg.d_ff, d, dtype),
        "cm_r": linear_init(jax.random.fold_in(rng, 99), d, d, dtype),
    }


def _ddlerp(p: dict, x: jax.Array, sx: jax.Array):
    """Data-dependent lerp between current and shifted token (5 targets)."""
    dx = sx - x
    xm = x + dx * p["mu_x"]
    low = jnp.tanh(xm @ p["ts_w1"]).reshape(*x.shape[:-1], 5, TSHIFT_RANK)
    dyn = jnp.einsum("...ct,ctd->...cd", low, p["ts_w2"])  # (..., 5, d)
    mix = p["mu"] + dyn
    return tuple(x + dx * mix[..., i, :] for i in range(5))


def _rkvwg(p: dict, x: jax.Array, sx: jax.Array, n: int, hd: int):
    xr, xk, xv, xw, xg = _ddlerp(p, x, sx)
    B = x.shape[0]
    shp = (B, n, hd)
    r = (xr @ p["wr"]["w"]).reshape(shp)
    k = (xk @ p["wk"]["w"]).reshape(shp)
    v = (xv @ p["wv"]["w"]).reshape(shp)
    g = xg @ p["wg"]["w"]
    w_log = p["w0"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32))).reshape(shp)  # decay in (0,1)
    return r, k, v, g, w


def _groupnorm(y: jax.Array, scale: jax.Array, n: int, hd: int) -> jax.Array:
    B = y.shape[0]
    yh = y.reshape(B, n, hd).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, -1, keepdims=True) + 1e-5)
    return (yh.reshape(B, n * hd) * scale).astype(y.dtype)


def time_mix(p: dict, x: jax.Array, state: RWKVState, cfg: ModelConfig):
    """Sequence time-mix: x (B, S, d) -> (y, new_state)."""
    B, S, d = x.shape
    hd = cfg.ssm.head_dim
    n = d // hd
    u = p["u"].reshape(n, hd).astype(jnp.float32)

    sx_seq = jnp.concatenate([state.sx_tm[:, None], x[:, :-1]], axis=1)

    def step(S_state, inp):
        xt, sxt = inp  # (B, d) each
        r, k, v, g, w = _rkvwg(p, xt, sxt, n, hd)
        rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
        kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
        y = jnp.einsum("bhk,bhkv->bhv", rf, S_state + u[None, :, :, None] * kv)
        S_state = w.astype(jnp.float32)[..., None] * S_state + kv
        yo = _groupnorm(y.reshape(B, d), p["ln_x_scale"], n, hd)
        return S_state, yo * jax.nn.silu(g)

    S_fin, ys = jax.lax.scan(step, state.S,
                             (x.transpose(1, 0, 2), sx_seq.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) @ p["wo"]["w"]
    return y, state._replace(S=S_fin, sx_tm=x[:, -1])


def channel_mix(p: dict, x: jax.Array, state: RWKVState):
    B, S, d = x.shape
    sx = jnp.concatenate([state.sx_cm[:, None], x[:, :-1]], axis=1)
    dx = sx - x
    xk = x + dx * p["mu_ck"]
    xr = x + dx * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]["w"]))
    y = jax.nn.sigmoid(xr @ p["cm_r"]["w"]) * (k @ p["cm_v"]["w"])
    return y, state._replace(sx_cm=x[:, -1])


def rwkv_init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> RWKVState:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    n = d // hd
    return RWKVState(jnp.zeros((batch, n, hd, hd), jnp.float32),
                     jnp.zeros((batch, d), dtype), jnp.zeros((batch, d), dtype))
