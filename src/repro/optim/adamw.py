"""AdamW with decoupled weight decay, global-norm clipping, and an optional
bf16 second-moment ("m8"-style) memory saving for trillion-param MoE runs."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


class AdamW(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params, *,
               lr_scale: jax.Array | float = 1.0):
        """Returns (new_params, new_state, grad_norm)."""
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            mhat = m_new / b1c
            vhat = v_new / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m_new.astype(m.dtype), v_new.astype(v.dtype))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_mu, new_nu), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
