from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.optim.schedule import warmup_cosine, warmup_linear

__all__ = ["AdamW", "AdamWState", "global_norm", "warmup_cosine", "warmup_linear"]
