"""Learning-rate schedules (linear warmup + cosine/linear decay)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32) + 1.0  # step 0 trains too
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos


def warmup_linear(step, *, warmup: int, total: int, floor: float = 0.0):
    step = jnp.asarray(step, jnp.float32) + 1.0
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    return warm * (1 - (1 - floor) * frac)
