"""Benchmark driver — one module per paper figure.  Prints
``name,us_per_call,derived`` CSV and writes JSON results.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5,fig7]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: quick shapes + minimum timing reps "
                         "(REPRO_BENCH_SMOKE=1); every registered fig script "
                         "must run end to end or the process fails")
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from benchmarks import (fig3_gemm, fig5_single_device, fig6_scaling,
                            fig7_end_to_end, fig8_imbalance, fig9_overlap,
                            fig10_train_step, fig11_serving, tab_capacity)
    suites = {
        "fig3": fig3_gemm.run,
        "fig5": fig5_single_device.run,
        "fig6": fig6_scaling.run,
        "fig7": fig7_end_to_end.run,
        "fig8": fig8_imbalance.run,
        "fig9": fig9_overlap.run,
        "fig10": fig10_train_step.run,
        "fig11": fig11_serving.run,
        "tab_capacity": tab_capacity.run,
    }
    picked = args.only.split(",") if args.only else list(suites)

    os.makedirs(args.out, exist_ok=True)
    # unified telemetry (repro.obs): every fig's rows also land in
    # <out>/metrics.jsonl via benchmarks.common, and per-fig wall times in a
    # Chrome trace next to them
    from benchmarks import common
    from repro.obs import trace as obs_trace
    common.set_results_dir(args.out)
    obs_trace.configure(enabled=True)
    # merge into existing results so `--only fig9` doesn't drop fig8's rows
    # (results.json also feeds repro.placement.calibrate)
    results = {}
    path = os.path.join(args.out, "results.json")
    if os.path.exists(path):
        try:
            with open(path) as f:
                results = json.load(f)
        except (OSError, ValueError):
            results = {}
    print("name,us_per_call,derived")
    for name in picked:
        t0 = time.time()
        with obs_trace.span(f"bench:{name}"):
            results[name] = suites[name](quick=args.quick)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    results["wire_summary"] = _wire_summary(results)
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1)
    common.set_results_dir(None)  # flush + close metrics.jsonl
    obs_trace.export(os.path.join(args.out, "trace.json"))
    print(f"# wrote {args.out}/results.json")
    print(f"# wrote {args.out}/metrics.jsonl and {args.out}/trace.json")


def _wire_summary(results: dict) -> dict:
    """Collect the measured-vs-modeled wire-byte evidence rows (fig9/fig10)
    into one top-level block (experiments/summarize.py renders it)."""
    out: dict = {}
    for row in results.get("fig9", []):
        for k in ("wire_bytes_serial", "hlo_bytes_serial",
                  "wire_bytes_pipelined", "hlo_bytes_pipelined",
                  "wire_bytes_bf16", "hlo_bytes_bf16"):
            if k in row:
                out.setdefault("fig9", {})[k] = row[k]
        # two-level ragged exchange: flat / dropless / auto-calibrated
        # bounds, with the inter-node (slow-link) share broken out
        h = row.get("hier") or {}
        for k in ("wire_bytes_flat", "hlo_bytes_flat", "wire_bytes_hier",
                  "hlo_bytes_hier", "wire_bytes_auto", "hlo_bytes_auto",
                  "wire_bytes_flat_inter", "wire_bytes_hier_intra",
                  "wire_bytes_hier_inter", "wire_bytes_auto_intra",
                  "wire_bytes_auto_inter"):
            if k in h:
                out.setdefault("fig9_hier", {})[k] = h[k]
    for row in results.get("fig10", []):
        if row.get("distributed") and "wire_bytes" in row:
            key = f"{row['dispatch']}_{row['wire_dtype']}"
            entry = {"wire_bytes": row["wire_bytes"],
                     "hlo_fwd_bytes": row["hlo_fwd_bytes"]}
            if "wire_bytes_inter" in row:
                entry["wire_bytes_intra"] = row["wire_bytes_intra"]
                entry["wire_bytes_inter"] = row["wire_bytes_inter"]
            out.setdefault("fig10", {})[key] = entry
    return out


if __name__ == "__main__":
    main()
