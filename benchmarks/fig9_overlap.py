"""Beyond-paper Fig 9: §5.2 smart-schedule overlap under the fig8 Zipf skew.

Serial baseline (one blocking all-to-all each way around the expert FFN) vs
the pipelined path (``DistConfig.overlap_chunks``: the exchange split into
capacity micro-shards, each a ppermute-decomposed all-to-all, expert compute
interleaved — repro/core/pipeline.py).  Same data-induced skew as fig8:
tokens drawn from per-expert Zipf-frequency cluster centers with the router
weight matrix as the center matrix.

Reported per row: median forward us serial vs pipelined, the pipeline depth,
and the exchange/compute interleaving evidence from compiled HLO — the
serial path's blocking ``all-to-all`` count vs the pipelined path's
``collective-permute`` count (the op XLA schedules asynchronously).  The
pipelined output must be bit-exact vs serial (acceptance criterion); the
subprocess asserts it before printing.

On the fake-device CPU mesh the timing delta is noise — collectives are
memcpys and XLA:CPU doesn't overlap them — so the numbers demonstrate the
schedule's *structure*; the win shows up on real ICI links.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

W = 4  # expert-parallel ranks (fake devices)
NB, DM, DH, K, E = 4096, 64, 128, 2, 16
ZIPF_A = 1.2
CHUNKS = 4

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={w}"
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.dispatch import expert_capacity

w, E, NB, DM, DH, K, CH = {w}, {e}, {nb}, {dm}, {dh}, {k}, {chunks}
cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                capacity_factor=2.0)
rng = np.random.RandomState(0)

# Zipf-clustered tokens: router columns = cluster centers (fig8 setup)
centers = rng.normal(size=(E, DM)).astype(np.float32)
centers /= np.linalg.norm(centers, axis=1, keepdims=True)
p = 1.0 / (np.arange(E) + 1) ** {zipf_a}
p /= p.sum()
z = rng.choice(E, size=NB, p=p)
x = jnp.asarray(centers[z] + 0.3 * rng.normal(size=(NB, DM)).astype(np.float32))
params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
params["router"]["w"] = jnp.asarray(centers.T * 4.0)

mesh = jax.make_mesh((1, w), ("data", "model"))
dist0 = fmoe.DistConfig(mesh, ("data", "model"))
dist1 = fmoe.DistConfig(mesh, ("data", "model"), overlap_chunks=CH)
dist_b = fmoe.DistConfig(mesh, ("data", "model"), wire_dtype="bf16")

def bench(dist):
    fn = jax.jit(lambda p_, x_: fmoe.fmoe_apply(p_, x_, cfg, dist=dist))
    with mesh:
        for _ in range(3):
            jax.block_until_ready(fn(params, x))
        ts = []
        for _ in range(16):
            t0 = time.perf_counter()
            y, m = fn(params, x)
            jax.block_until_ready(y)
            ts.append(time.perf_counter() - t0)
        # lower the FULL (y, metrics) program: the wire-byte comparison must
        # see the counts exchange too (a [0]-only lowering would DCE it)
        txt = fn.lower(params, x).compile().as_text()
    return float(np.median(ts) * 1e6), np.asarray(y), m, txt

from repro.launch.roofline import collective_bytes

def hlo_wire(txt):
    cb = collective_bytes(txt)
    return float(cb.get("all-to-all", 0) + cb.get("collective-permute", 0))

us0, y0, m0, hlo0 = bench(dist0)
us1, y1, m1, hlo1 = bench(dist1)
us_b, _, m_b, hlo_b = bench(dist_b)
assert (y0 == y1).all(), "pipelined path must be bit-exact vs serial"
# measured (device counter) vs modeled (optimized-HLO exchange output bytes)
# must agree: the counter is the same quantity computed at trace time
pairs = {{"serial": (float(m0.obs.wire_bytes), hlo_wire(hlo0)),
          "pipelined": (float(m1.obs.wire_bytes), hlo_wire(hlo1)),
          "bf16": (float(m_b.obs.wire_bytes), hlo_wire(hlo_b))}}
for name, (meas, model) in pairs.items():
    assert abs(meas - model) <= 0.10 * max(model, 1.0), (
        f"{{name}}: counter {{meas}} vs HLO {{model}}")
assert 0.4 <= pairs["bf16"][0] / pairs["serial"][0] <= 0.6, (
    "bf16 wire must be ~half of f32")
a2a0 = hlo0.count("all-to-all")
cp1 = hlo1.count("collective-permute")
cap = expert_capacity(NB // w, E, K, cfg.capacity_factor)
chunk_elems = (E * (cap // CH)) * DM  # per-chunk payload per rank, one way

# ---- two-level (hierarchical) ragged exchange under Zipf skew ----
# Same cluster construction, but the Zipf ranks interleave across the two
# nodes (hot experts alternate), so the *actual* per-node load sits well
# below the dropless worst case — the adaptive bounds turn that measured
# headroom into fewer inter-node wire bytes.
from types import SimpleNamespace
from repro.core.monitor import LoadMonitor

cfg_r = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                  dispatch="ragged", capacity_factor=2.0)
n_nodes, n_inner = 2, w // 2
mesh_h = jax.make_mesh((1, n_nodes, n_inner), ("data", "node", "model"))
AXH = ("data", "node", "model")
zr = np.empty(E, np.int64)  # expert -> interleaved Zipf rank
zr[:E // 2], zr[E // 2:] = 2 * np.arange(E // 2), 2 * np.arange(E // 2) + 1
ph = (1.0 / (zr + 1) ** {zipf_a}); ph /= ph.sum()
zh = rng.choice(E, size=NB, p=ph)
xh = jnp.asarray(centers[zh]
                 + 0.3 * rng.normal(size=(NB, DM)).astype(np.float32))

def bench_h(dist):
    fn = jax.jit(lambda p_, x_: fmoe.fmoe_apply(p_, x_, cfg_r, dist=dist))
    with mesh_h:
        for _ in range(3):
            jax.block_until_ready(fn(params, xh))
        ts = []
        for _ in range(16):
            t0 = time.perf_counter()
            y, m = fn(params, xh)
            jax.block_until_ready(y)
            ts.append(time.perf_counter() - t0)
        txt = fn.lower(params, xh).compile().as_text()
    return float(np.median(ts) * 1e6), np.asarray(y), m, txt

flat_r = fmoe.DistConfig(mesh_h, AXH, expert_axis=("node", "model"))
us_f, y_f, m_f, hlo_f = bench_h(flat_r)
us_h, y_h, m_h, hlo_h = bench_h(flat_r._replace(node_axis="node"))
assert (y_f == y_h).all(), "two-level exchange must be bit-exact vs flat"

# --ragged_bound auto, by hand: calibrate both bounds from the measured
# per-expert load (one exact-load update; ema=0 keeps it undamped)
mon = LoadMonitor(E, ema=0.0)
mon.update(SimpleNamespace(load=np.asarray(m_f.load), drop_frac=0.0))
mp_h, t_local = n_nodes * n_inner, NB // w
rb = mon.suggest_ragged_bound(t_local, K, mp_h)
ib = mon.suggest_ragged_bound(t_local * n_inner, K, mp_h)
assert rb < t_local * K and ib < n_inner * t_local * K, (
    "adaptive bounds must sit below the dropless worst case")
us_s, y_s, m_s, hlo_s = bench_h(flat_r._replace(
    node_axis="node", ragged_bound=rb, inter_bound=ib))
assert float(m_s.drop_frac) <= 0.01, float(m_s.drop_frac)
assert float(m_s.obs.wire_bytes_inter) < float(m_h.obs.wire_bytes_inter)
assert float(m_s.obs.wire_bytes_inter) < float(m_f.obs.wire_bytes_inter)
hier_pairs = {{"hier_flat": (float(m_f.obs.wire_bytes), hlo_wire(hlo_f)),
               "hier": (float(m_h.obs.wire_bytes), hlo_wire(hlo_h)),
               "hier_auto": (float(m_s.obs.wire_bytes), hlo_wire(hlo_s))}}
for name, (meas, model) in hier_pairs.items():
    assert abs(meas - model) <= 0.10 * max(model, 1.0), (
        f"{{name}}: counter {{meas}} vs HLO {{model}}")

import json
print("RESULTJSON " + json.dumps({{
    "us0": us0, "us1": us1, "ch": CH, "a2a0": a2a0, "cp1": cp1,
    "chunk_elems": chunk_elems,
    "wire_bytes_serial": pairs["serial"][0],
    "hlo_bytes_serial": pairs["serial"][1],
    "wire_bytes_pipelined": pairs["pipelined"][0],
    "hlo_bytes_pipelined": pairs["pipelined"][1],
    "wire_bytes_bf16": pairs["bf16"][0],
    "hlo_bytes_bf16": pairs["bf16"][1],
    "hier": {{
        "us_flat": us_f, "us_hier": us_h, "us_hier_auto": us_s,
        "ragged_bound_auto": rb, "inter_bound_auto": ib,
        "dropless_bound": t_local * K,
        "dropless_inter_bound": n_inner * t_local * K,
        "drop_frac_auto": float(m_s.drop_frac), "bit_exact": True,
        "wire_bytes_flat_inter": float(m_f.obs.wire_bytes_inter),
        "wire_bytes_hier_intra": float(m_h.obs.wire_bytes_intra),
        "wire_bytes_hier_inter": float(m_h.obs.wire_bytes_inter),
        "wire_bytes_auto_intra": float(m_s.obs.wire_bytes_intra),
        "wire_bytes_auto_inter": float(m_s.obs.wire_bytes_inter),
        "wire_bytes_flat": hier_pairs["hier_flat"][0],
        "hlo_bytes_flat": hier_pairs["hier_flat"][1],
        "wire_bytes_hier": hier_pairs["hier"][0],
        "hlo_bytes_hier": hier_pairs["hier"][1],
        "wire_bytes_auto": hier_pairs["hier_auto"][0],
        "hlo_bytes_auto": hier_pairs["hier_auto"][1]}}}}))
"""


def run(quick: bool = False) -> list[dict]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    nb = NB // 2 if quick else NB
    script = _SCRIPT.format(w=W, e=E, nb=nb, dm=DM, dh=DH, k=K,
                            zipf_a=ZIPF_A, chunks=CHUNKS)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    import json

    import jax  # backend tag gates cost-model calibration (placement/calibrate)
    vals = json.loads(out.stdout.strip().split("RESULTJSON ")[1].splitlines()[0])
    row = {
        "us_serial": vals["us0"], "us_pipelined": vals["us1"],
        "n_chunks": vals["ch"], "hlo_all_to_all_serial": vals["a2a0"],
        "hlo_collective_permute_pipelined": vals["cp1"],
        "chunk_elems": vals["chunk_elems"], "bit_exact": True,
        # wire-byte evidence: device-side counter vs optimized-HLO exchange
        # bytes (asserted within 10% in-subprocess before printing)
        "wire_bytes_serial": vals["wire_bytes_serial"],
        "hlo_bytes_serial": vals["hlo_bytes_serial"],
        "wire_bytes_pipelined": vals["wire_bytes_pipelined"],
        "hlo_bytes_pipelined": vals["hlo_bytes_pipelined"],
        "wire_bytes_bf16": vals["wire_bytes_bf16"],
        "hlo_bytes_bf16": vals["hlo_bytes_bf16"],
        # two-level ragged exchange on the (1, 2, 2) node mesh under the
        # interleaved Zipf skew, with LoadMonitor-calibrated bounds
        "hier": vals["hier"],
        "backend": jax.default_backend(),
    }
    emit("fig9_serial", row["us_serial"],
         f"all_to_all_ops={row['hlo_all_to_all_serial']} "
         f"wire_bytes={row['wire_bytes_serial']:.0f}")
    emit("fig9_pipelined", row["us_pipelined"],
         f"chunks={row['n_chunks']} "
         f"collective_permutes={row['hlo_collective_permute_pipelined']} "
         f"chunk_elems={row['chunk_elems']} bit_exact=True "
         f"wire_bytes={row['wire_bytes_pipelined']:.0f}")
    h = vals["hier"]
    emit("fig9_hier_flat", h["us_flat"],
         f"inter_bytes={h['wire_bytes_flat_inter']:.0f} (flat: all inter)")
    emit("fig9_hier", h["us_hier"],
         f"bit_exact=True intra={h['wire_bytes_hier_intra']:.0f} "
         f"inter={h['wire_bytes_hier_inter']:.0f}")
    emit("fig9_hier_auto", h["us_hier_auto"],
         f"bound={h['ragged_bound_auto']}/{h['dropless_bound']} "
         f"inter_bound={h['inter_bound_auto']}/{h['dropless_inter_bound']} "
         f"inter={h['wire_bytes_auto_inter']:.0f} "
         f"drop={h['drop_frac_auto']:.3f}")
    return [row]
