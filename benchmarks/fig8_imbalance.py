"""Beyond-paper Fig 8: step time under Zipf-skewed routing, expert placement
off vs on (the §6 load-balance loop closed by repro/placement/).

Skew is induced the way production skew arrives — through the data, not the
gate: tokens are drawn from per-expert cluster centers with Zipf frequencies
and the router weight matrix IS the center matrix, so top-1 routing follows
the cluster distribution.  One measurement process per setting (fake host
devices, same contract as fig6): baseline a2a, then the planner's layout
(shadowed hot experts + shrunk exchange buffer) after migrating the params.

Reported per row: median forward us, modeled a2a buffer elements per rank,
observed drop fraction, shadow count and capacity scale.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

W = 4  # expert-parallel ranks (fake devices)
NB, DM, DH, K, E = 4096, 64, 128, 2, 16
ZIPF_A = 1.2

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={w}"
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.dispatch import expert_capacity
from repro.placement import from_logical, plan_placement, shadow_spec

w, E, NB, DM, DH, K = {w}, {e}, {nb}, {dm}, {dh}, {k}
cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                capacity_factor=2.0)
rng = np.random.RandomState(0)

# Zipf-clustered tokens: router columns = cluster centers
centers = rng.normal(size=(E, DM)).astype(np.float32)
centers /= np.linalg.norm(centers, axis=1, keepdims=True)
p = 1.0 / (np.arange(E) + 1) ** {zipf_a}
p /= p.sum()
z = rng.choice(E, size=NB, p=p)
x = jnp.asarray(centers[z] + 0.3 * rng.normal(size=(NB, DM)).astype(np.float32))
params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
params["router"]["w"] = jnp.asarray(centers.T * 4.0)

mesh = jax.make_mesh((1, w), ("data", "model"))
dist0 = fmoe.DistConfig(mesh, ("data", "model"))

def bench(dist, prm):
    fn = jax.jit(lambda p_, x_: fmoe.fmoe_apply(p_, x_, cfg, dist=dist))
    with mesh:
        for _ in range(3):
            jax.block_until_ready(fn(prm, x))
        ts = []
        for _ in range(16):
            t0 = time.perf_counter()
            y, m = fn(prm, x)
            jax.block_until_ready(y)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e6), np.asarray(m.load), float(m.drop_frac)

us0, load, drop0 = bench(dist0, params)
cap = expert_capacity(NB // w, E, K, cfg.capacity_factor)
plan = plan_placement(load, w, d_model=DM, d_hidden=DH, capacity=cap,
                      capacity_factor=cfg.capacity_factor)
spec = shadow_spec(plan, E, cap)
base_elems = E * cap * DM
dist1 = fmoe.DistConfig(mesh, ("data", "model"), placement=plan)
us1, load1, drop1 = bench(dist1, from_logical(params, plan))
assert np.allclose(load1, load, atol=1e-6), "placement must not change routing"
imb = float(load.max() * E)
print(f"RESULT {{us0:.1f}} {{us1:.1f}} {{base_elems}} {{spec.a2a_elems(DM)}} "
      f"{{drop0:.4f}} {{drop1:.4f}} {{plan.num_shadow}} "
      f"{{plan.capacity_scale:.3f}} {{imb:.2f}}")
"""


def run(quick: bool = False) -> list[dict]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    # quick halves tokens AND experts' hidden dim together: shadowing pays
    # when a2a slice bytes (C*d) beat weight-sync bytes (~3*d*h), so scale
    # both or the small regime stops demonstrating the mechanism
    nb, dh = (NB // 2, DH // 2) if quick else (NB, DH)
    script = _SCRIPT.format(w=W, e=E, nb=nb, dm=DM, dh=dh, k=K,
                            zipf_a=ZIPF_A)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    vals = out.stdout.strip().split("RESULT ")[1].split()
    us0, us1 = float(vals[0]), float(vals[1])
    elems0, elems1 = int(vals[2]), int(vals[3])
    import jax  # backend tag gates cost-model calibration (placement/calibrate)
    row = {
        "us_off": us0, "us_on": us1,
        "a2a_elems_off": elems0, "a2a_elems_on": elems1,
        "drop_off": float(vals[4]), "drop_on": float(vals[5]),
        "num_shadow": int(vals[6]), "capacity_scale": float(vals[7]),
        "imbalance": float(vals[8]), "backend": jax.default_backend(),
    }
    emit("fig8_placement_off", us0,
         f"a2a_elems={elems0} drop={row['drop_off']:.3f} imb={row['imbalance']:.2f}")
    emit("fig8_placement_on", us1,
         f"a2a_elems={elems1} shadow={row['num_shadow']} "
         f"cap_scale={row['capacity_scale']:.2f} drop={row['drop_on']:.3f}")
    return [row]
