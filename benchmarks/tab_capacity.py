"""Capacity-factor ablation (extends the paper's §4 load-imbalance
discussion): token drop rate, routing imbalance, and step latency vs the
static capacity factor — the knob the TPU adaptation introduces in place of
FastMoE's dynamic buffers (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.core.monitor import LoadMonitor

FACTORS = [0.5, 1.0, 1.25, 2.0, 4.0]
NB, DM, DH, E, K = 1024, 128, 256, 8, 2


def run(quick: bool = False) -> list[dict]:
    x = jax.random.normal(jax.random.PRNGKey(0), (NB, DM), jnp.float32)
    rows = []
    for cf in (FACTORS[1:4] if quick else FACTORS):
        cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                        capacity_factor=cf)
        params = fmoe.fmoe_init(jax.random.PRNGKey(1), DM, cfg)
        fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg))
        y, m = fn(params, x)
        mon = LoadMonitor(E, ema=0.0)
        mon.update(m)
        t = timeit(lambda p, x: fn(p, x)[0], params, x)
        row = {"capacity_factor": cf, "drop_frac": float(m.drop_frac),
               "imbalance": mon.imbalance, "us": t["us"]}
        emit(f"tab_capacity_cf{cf}", t["us"],
             f"drop={row['drop_frac']:.3f} imbalance={row['imbalance']:.2f}")
        rows.append(row)
    # drops must be monotone non-increasing in capacity
    drops = [r["drop_frac"] for r in rows]
    assert all(a >= b - 1e-9 for a, b in zip(drops, drops[1:])), drops
    return rows
