"""Paper Fig 7: end-to-end GPT training — MoE vs dense at equal *active*
FLOPs (d_h halved, top-2, §5.4).

Paper claims: (a) the MoE model is slower per step (more compute +
communication — they report ~3x), but (b) reaches LOWER loss at the same
iteration count thanks to the enlarged parameter count.  CPU-scaled GPT
(2 layers, d=128, 8 experts) trained on the structured synthetic stream.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig
from repro.data import SyntheticLM
from repro.launch.train import make_train_step
from repro.models import lm
from repro.optim import AdamW


def _gpt(moe: bool) -> ModelConfig:
    d = 96
    return ModelConfig(
        name="gpt-moe" if moe else "gpt-dense",
        family="moe" if moe else "dense",
        num_layers=2, d_model=d, d_ff=4 * d, vocab_size=2048,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=d // 4),
        # d_h halved (384 -> 192) so top-2 active FLOPs match dense (§5.4)
        moe=MoEConfig(num_experts=16, top_k=2, d_expert_hidden=2 * d,
                      capacity_factor=2.0) if moe else None,
        norm="layernorm", act="gelu",
        dtype="float32", param_dtype="float32", remat="none")


def _data(cfg: ModelConfig) -> SyntheticLM:
    # Markov-heavy stream: predicting the successor set is an FFN-capacity
    # task, so the MoE's extra parameters have something to buy.
    return SyntheticLM(cfg.vocab_size, 64, seed=0, zipf_a=1.1,
                       markov_weight=0.85)


def _train(cfg: ModelConfig, steps: int):
    data = _data(cfg)
    opt = AdamW(lr=3e-3)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, warmup=20, total_steps=steps))
    losses = []
    t0 = time.time()
    for i, batch in enumerate(data.batches(16)):
        if i >= steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    wall = time.time() - t0
    # held-out eval: fresh sampling of the SAME distribution
    ev = _data(cfg).reseed_sampler(999)
    eval_losses = []
    for i, batch in enumerate(ev.batches(16)):
        if i >= 8:
            break
        loss, _ = lm.loss_fn(params, cfg,
                             {k: jnp.asarray(v) for k, v in batch.items()})
        eval_losses.append(float(loss))
    return losses, wall / steps, float(np.mean(eval_losses))


def run(quick: bool = False) -> dict:
    steps = 60 if quick else 400
    moe_losses, moe_step_s, moe_eval = _train(_gpt(True), steps)
    dense_losses, dense_step_s, dense_eval = _train(_gpt(False), steps)
    slowdown = moe_step_s / dense_step_s
    emit("fig7_moe_step", moe_step_s * 1e6, f"eval_loss={moe_eval:.4f}")
    emit("fig7_dense_step", dense_step_s * 1e6, f"eval_loss={dense_eval:.4f}")
    emit("fig7_summary", 0.0,
         f"moe_slowdown=x{slowdown:.2f} deval={dense_eval - moe_eval:+.4f} "
         f"(positive => MoE better, paper Fig 7)")
    if not quick:  # the paper's claim, at full step count
        assert moe_eval < dense_eval, (moe_eval, dense_eval)
    return {"moe_losses": moe_losses, "dense_losses": dense_losses,
            "moe_step_s": moe_step_s, "dense_step_s": dense_step_s,
            "moe_eval": moe_eval, "dense_eval": dense_eval,
            "slowdown": slowdown}
