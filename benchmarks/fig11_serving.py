"""Beyond-paper Fig 11: continuous-batching serving throughput/latency.

A Zipf-skewed, Poisson-arrival request stream with mixed generation lengths
is served three ways on the SAME decode path (launch/scheduler):

* ``static``     — whole-batch admission: a new wave only starts when every
                   slot is free, so short requests wait on the batch's
                   longest (the classic serving baseline).
* ``continuous`` — per-tick admit/retire into fixed decode slots over the
                   paged KV cache (vLLM-style in-flight batching).
* ``continuous+replan`` — same, plus the online placement loop: the decode
                   step's (L, E) expert-load feed drives the
                   PlacementController and accepted plans migrate live
                   params between ticks (bitwise-invisible in the stream —
                   tests/test_scheduler proves it differentially).

Skew arrives through the data like fig8: token embeddings cluster around
per-expert router centers and prompt tokens are drawn Zipf over the vocab,
so decode traffic genuinely imbalances the experts and the replan arm has
something to fix.  Reported per mode: tokens/sec, per-token p50/p99
latency, decode ticks, live replans.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import record, smoke_mode

W = 4  # fake host devices -> 1x4 mesh
REPLAN_EVERY = 8

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={w}"
import dataclasses, json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import lm
from repro.launch.scheduler import ContinuousBatcher
from repro.launch.serve_api import Request, ServeConfig

SLOTS, NREQ, EVERY = {slots}, {nreq}, {every}

# 8 experts on 4 ranks with small expert FFNs: the scale where the cost
# model's shadow-weight overhead is beatable and serve-time replans pay
cfg = reduced(get_config("fastmoe-gpt"), num_layers=2, d_model=64,
              max_experts=8)
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, d_expert_hidden=32))
E, DM, V = cfg.moe.num_experts, cfg.d_model, cfg.vocab_size
params = lm.init_params(jax.random.PRNGKey(0), cfg)

# fig8's skew-through-the-data idiom: embeddings cluster around router
# centers, cluster frequencies are Zipf, router columns ARE the centers
rng = np.random.RandomState(0)
centers = rng.normal(size=(E, DM)).astype(np.float32)
centers /= np.linalg.norm(centers, axis=1, keepdims=True)
zipf = 1.0 / (np.arange(E) + 1) ** 1.2
tok_cluster = rng.choice(E, size=V, p=zipf / zipf.sum())
params["embed"]["table"] = jnp.asarray(
    centers[tok_cluster] + 0.1 * rng.normal(size=(V, DM)).astype(np.float32))
params["layers"]["ffn"]["router"]["w"] = jnp.broadcast_to(
    jnp.asarray(centers.T * 4.0), (cfg.num_layers, DM, E)).astype(
        params["layers"]["ffn"]["router"]["w"].dtype)

# the request stream: Zipf token ids, mixed generation lengths (short
# chats + long completions — what head-of-line blocking punishes),
# Poisson arrivals measured in decode ticks
pv = 1.0 / (np.arange(V) + 1) ** 1.1
pv /= pv.sum()
sr = np.random.RandomState(1)
gens = [2 if i % 2 else 18 for i in range(NREQ)]
stream = [dict(id=i, prompt=sr.choice(V, size=int(sr.randint(4, 12)),
                                      p=pv).astype(np.int32),
               max_new_tokens=gens[i]) for i in range(NREQ)]
arrivals = np.cumsum(sr.poisson(0.5, size=NREQ))  # arrival tick per request

def serve(policy, replan_every):
    scfg = ServeConfig(slots=SLOTS, max_len=32, block_size=8, mesh="1x{mw}",
                       policy=policy, replan_every=replan_every)
    b = ContinuousBatcher(params, cfg, scfg)
    nxt = 0
    t0 = time.time()
    while nxt < NREQ or b.queue or any(s is not None for s in b.slots):
        while nxt < NREQ and arrivals[nxt] <= b.ticks:
            b.submit(Request(arrival=t0, **stream[nxt]))
            nxt += 1
        if b.step() == 0 and nxt < NREQ:
            b.ticks += 1  # idle tick while the stream is still arriving
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in b.completions)
    lats = sorted(l for c in b.completions for l in c.latencies[1:]) or [0.0]
    return dict(mode=policy if not replan_every else "continuous+replan",
                tok_s=toks / max(dt, 1e-9), ticks=b.ticks, tokens=toks,
                requests=len(b.completions), replans=b.replans,
                p50_ms=lats[len(lats) // 2] * 1e3,
                p99_ms=lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3)

rows = [serve("static", 0), serve("continuous", 0),
        serve("continuous", EVERY)]
assert rows[0]["tokens"] == rows[1]["tokens"] == rows[2]["tokens"]
assert rows[1]["ticks"] < rows[0]["ticks"], "continuous must save ticks"
print("RESULT " + json.dumps(rows))
"""


def run(quick: bool = False) -> list[dict]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    # the replan arm needs enough decode slots that the modeled a2a savings
    # beat the shadow-weight cost (see the controller's cost model); smoke
    # only proves the three modes run and continuous beats static
    slots, nreq = (8, 24) if (quick or smoke_mode()) else (32, 120)
    script = _SCRIPT.format(w=W, mw=W, slots=slots, nreq=nreq,
                            every=REPLAN_EVERY)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    import json

    import jax
    rows = json.loads(out.stdout.strip().split("RESULT ")[1])
    static, cont = rows[0], rows[1]
    if cont["tok_s"] <= static["tok_s"]:
        raise RuntimeError(
            f"continuous batching must beat static admission: "
            f"{cont['tok_s']:.1f} <= {static['tok_s']:.1f} tok/s "
            f"(ticks {cont['ticks']} vs {static['ticks']})")
    for r in rows:
        r["slots"] = slots
        r["backend"] = jax.default_backend()
        record({"bench": "fig11", **r})
        print(f"fig11,{r['mode']},{r['tok_s']:.1f} tok/s,"
              f"p50={r['p50_ms']:.1f}ms p99={r['p99_ms']:.1f}ms "
              f"ticks={r['ticks']} replans={r['replans']}")
    return rows
