"""Paper Fig 6: cross-worker scalability of distributed expert parallelism.

Each worker count runs in a subprocess with that many fake host devices; the
distributed a2a MoE layer (paper §3.2) executes real all-to-alls through
XLA's collective machinery.  Throughput = expert-GeMM FLOPs / wall time,
matching the paper's metric.  NOTE: fake devices share one CPU, so absolute
scaling is bounded by the host — the deliverable is that the multi-worker
path *works* and its throughput accounting is honest (the paper itself
reports sub-linear scaling).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

WORKERS = [1, 2, 4, 8]
NB, DM, DH, K, NE = 1024, 128, 512, 2, 4  # paper: ne=4 experts per worker

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={w}"
import time, jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.core import fmoe
w = {w}
E = {ne} * w  # ne experts per worker (paper §5.3)
cfg = MoEConfig(num_experts=E, top_k={k}, d_expert_hidden={dh}, capacity_factor=2.0)
params = fmoe.fmoe_init(jax.random.PRNGKey(0), {dm}, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), ({nb}, {dm}), jnp.float32)
if w == 1:
    fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg)[0])
    ctx = None
else:
    mesh = jax.make_mesh((1, w), ("data", "model"))
    dist = fmoe.DistConfig(mesh, ("data", "model"))
    fn = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg, dist=dist)[0])
    ctx = mesh
def run():
    if ctx is not None:
        with ctx:
            return fn(params, x)
    return fn(params, x)
for _ in range(3):
    jax.block_until_ready(run())
ts = []
for _ in range(8):
    t0 = time.perf_counter(); jax.block_until_ready(run())
    ts.append(time.perf_counter() - t0)
import numpy as np
dt = float(np.median(ts))
flops = 2 * {nb} * {k} * 2 * {dm} * {dh} * 3  # swiglu: 3 projections
print(f"RESULT {{dt*1e6:.1f}} {{flops/dt/1e9:.2f}}")
"""


def run(quick: bool = False) -> list[dict]:
    rows = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for w in (WORKERS[:3] if quick else WORKERS):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(root, "src")
        env.pop("XLA_FLAGS", None)
        script = _SCRIPT.format(w=w, nb=NB, dm=DM, dh=DH, k=K, ne=NE)
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                             capture_output=True, text=True, env=env,
                             timeout=560)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-2000:])
        us, gflops = out.stdout.strip().split("RESULT ")[1].split()
        emit(f"fig6_workers{w}", float(us), f"{gflops}GFLOP/s "
             f"E={NE * w}")
        rows.append({"workers": w, "us": float(us), "gflops": float(gflops)})
    return rows
