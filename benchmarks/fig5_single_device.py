"""Paper Fig 5: single-device MoE latency, FastMoE vs the naive baseline
(Rau 2019), forward and forward+backward, sweeping the number of experts.

Paper claim: the baseline's time grows with num_experts while FastMoE stays
roughly flat (its batched dispatch does the same total work regardless of E).
CPU-scaled: n_b=512, d_m=128, d_h=512, k=2 (paper: 4096/1024/4096/2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.base import MoEConfig
from repro.core import fmoe, naive

NB, DM, DH, K = 512, 128, 512, 2
EXPERTS = [2, 4, 8, 16]


def run(quick: bool = False) -> list[dict]:
    x = jax.random.normal(jax.random.PRNGKey(0), (NB, DM), jnp.float32)
    rows = []
    experts = EXPERTS[:3] if quick else EXPERTS
    for E in experts:
        cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                        capacity_factor=2.0)
        params = fmoe.fmoe_init(jax.random.PRNGKey(E), DM, cfg)

        fast_fwd = jax.jit(lambda p, x: fmoe.fmoe_apply(p, x, cfg)[0])
        naive_fwd = jax.jit(lambda p, x: naive.moe_loop_masked(p, x, cfg))
        fast_bwd = jax.jit(jax.grad(lambda p, x: (fmoe.fmoe_apply(p, x, cfg)[0] ** 2).mean()))
        naive_bwd = jax.jit(jax.grad(lambda p, x: (naive.moe_loop_masked(p, x, cfg) ** 2).mean()))

        r = {"experts": E}
        for label, fn in [("fastmoe_fwd", fast_fwd), ("baseline_fwd", naive_fwd),
                          ("fastmoe_bwd", fast_bwd), ("baseline_bwd", naive_bwd)]:
            t = timeit(fn, params, x)
            emit(f"fig5_{label}_E{E}", t["us"])
            r[label] = t["us"]
        rows.append(r)
    # paper claim: baseline scales with E, FastMoE much less
    base_growth = rows[-1]["baseline_fwd"] / rows[0]["baseline_fwd"]
    fast_growth = rows[-1]["fastmoe_fwd"] / rows[0]["fastmoe_fwd"]
    emit("fig5_growth_ratio", 0.0,
         f"baseline x{base_growth:.2f} vs fastmoe x{fast_growth:.2f} "
         f"over E={rows[0]['experts']}->{rows[-1]['experts']}")
    assert rows[-1]["fastmoe_fwd"] < rows[-1]["baseline_fwd"], rows[-1]
    return rows
