"""Beyond-paper Fig 10: full train-step (fwd+bwd) time for the MoE layer —
two-pass vs fused expert kernels, capacity vs ragged (dropless) dispatch.

The fused path's claim is a *training* claim: with the fused backward
(repro/kernels/fused_ffn_bwd.py) a value_and_grad step never materializes
the (M, H) hidden activation — or its gradient — in HBM on any dispatch
mode.  Each row reports the measured step time plus the structural evidence
from the jaxpr: whether any (rows >= M, H)-shaped intermediate exists in
the differentiated program.

On CPU the Pallas kernels run in interpret mode, so absolute times favor
the XLA two-pass path; the HBM-traffic win shows on real TPUs.  The
``materializes_mh`` column is the backend-independent evidence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

T, DM, DH, E, K = 256, 64, 128, 8, 2


def _materializes_mh(fn, *args, min_rows: int, hidden: int) -> bool:
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            s = getattr(v.aval, "shape", ())
            if len(s) == 2 and s[1] == hidden and s[0] >= min_rows:
                return True
    return False


def run(quick: bool = False) -> list[dict]:
    import dataclasses

    from repro.configs.base import MoEConfig
    from repro.core import fmoe

    t = T // 2 if quick else T
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(1), (t, DM))
    for dispatch in ("capacity", "ragged"):
        cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                        dispatch=dispatch)
        params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
        for impl in ("pallas", "fused"):
            def loss(p, x, impl=impl, cfg=cfg):
                y, _ = fmoe.fmoe_apply(p, x, cfg, impl=impl)
                return (y ** 2).mean()

            step = jax.jit(jax.value_and_grad(loss))
            res = timeit(step, params, x)
            mh = _materializes_mh(jax.value_and_grad(loss), params, x,
                                  min_rows=t * K, hidden=DH)
            row = {"impl": impl, "dispatch": dispatch, "us": res["us"],
                   "std_us": res["std_us"], "materializes_mh": mh,
                   "tokens": t, "backend": jax.default_backend()}
            rows.append(row)
            emit(f"fig10_{dispatch}_{impl}", row["us"],
                 f"fwd+bwd materializes_MH={mh}")
            assert (impl == "fused") == (not mh), (
                "fused step must not materialize (M, H); two-pass must")
    return rows
