"""Beyond-paper Fig 10: full train-step (fwd+bwd) time for the MoE layer —
two-pass vs fused expert kernels, capacity vs ragged (dropless) dispatch.

The fused path's claim is a *training* claim: with the fused backward
(repro/kernels/fused_ffn_bwd.py) a value_and_grad step never materializes
the (M, H) hidden activation — or its gradient — in HBM on any dispatch
mode.  Each row reports the measured step time plus the structural evidence
from the jaxpr: whether any (rows >= M, H)-shaped intermediate exists in
the differentiated program.

On CPU the Pallas kernels run in interpret mode, so absolute times favor
the XLA two-pass path; the HBM-traffic win shows on real TPUs.  The
``materializes_mh`` column is the backend-independent evidence.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

T, DM, DH, E, K = 256, 64, 128, 8, 2
W = 4  # expert-parallel ranks for the distributed wire-evidence rows

# Distributed wire evidence: one fwd+bwd value_and_grad step per (dispatch,
# wire dtype), with the device-side wire counter checked against the
# *forward* program's optimized-HLO exchange bytes (the counter models the
# forward exchange; the backward adds its mirror image on top).
_DIST_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={w}"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.core import fmoe
from repro.launch.roofline import collective_bytes

w, E, T, DM, DH, K = {w}, {e}, {t}, {dm}, {dh}, {k}
x = jax.random.normal(jax.random.PRNGKey(1), (T, DM))
mesh = jax.make_mesh((1, w), ("data", "model"))
rows = []
for dispatch in ("capacity", "ragged"):
    cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                    dispatch=dispatch, capacity_factor=2.0)
    params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
    for wire in (None, "bf16"):
        dist = fmoe.DistConfig(mesh, ("data", "model"), wire_dtype=wire)

        def fwd(p, x_):
            return fmoe.fmoe_apply(p, x_, cfg, dist=dist)

        def loss(p, x_):
            y, m = fwd(p, x_)
            return (y ** 2).mean(), m

        step = jax.jit(jax.value_and_grad(loss, has_aux=True))
        with mesh:
            import time
            for _ in range(2):
                jax.block_until_ready(step(params, x)[0][0])
            ts = []
            for _ in range(8):
                t0 = time.perf_counter()
                (l, m), g = step(params, x)
                jax.block_until_ready(l)
                ts.append(time.perf_counter() - t0)
            ftxt = jax.jit(fwd).lower(params, x).compile().as_text()
        cb = collective_bytes(ftxt)
        hlo_wire = float(cb.get("all-to-all", 0)
                         + cb.get("collective-permute", 0))
        meas = float(m.obs.wire_bytes)
        assert abs(meas - hlo_wire) <= 0.10 * max(hlo_wire, 1.0), (
            f"{{dispatch}}/{{wire}}: counter {{meas}} vs fwd HLO {{hlo_wire}}")
        rows.append({{"dispatch": dispatch, "wire_dtype": wire or "f32",
                      "us": float(np.median(ts) * 1e6),
                      "wire_bytes": meas, "hlo_fwd_bytes": hlo_wire,
                      "dropped": float(m.obs.dropped),
                      "imbalance": float(m.obs.imbalance)}})

# two-level ragged exchange on the (data, node, model) mesh: same fwd+bwd
# step, wire counter split intra/inter and checked against the fwd HLO
mesh_h = jax.make_mesh((1, 2, w // 2), ("data", "node", "model"))
cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                dispatch="ragged", capacity_factor=2.0)
params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
for wire in (None, "bf16"):
    dist = fmoe.DistConfig(mesh_h, ("data", "node", "model"),
                           expert_axis=("node", "model"), node_axis="node",
                           wire_dtype=wire)

    def fwd(p, x_):
        return fmoe.fmoe_apply(p, x_, cfg, dist=dist)

    def loss(p, x_):
        y, m = fwd(p, x_)
        return (y ** 2).mean(), m

    step = jax.jit(jax.value_and_grad(loss, has_aux=True))
    with mesh_h:
        import time
        for _ in range(2):
            jax.block_until_ready(step(params, x)[0][0])
        ts = []
        for _ in range(8):
            t0 = time.perf_counter()
            (l, m), g = step(params, x)
            jax.block_until_ready(l)
            ts.append(time.perf_counter() - t0)
        ftxt = jax.jit(fwd).lower(params, x).compile().as_text()
    cb = collective_bytes(ftxt)
    hlo_wire = float(cb.get("all-to-all", 0)
                     + cb.get("collective-permute", 0))
    meas = float(m.obs.wire_bytes)
    assert abs(meas - hlo_wire) <= 0.10 * max(hlo_wire, 1.0), (
        f"hier/{{wire}}: counter {{meas}} vs fwd HLO {{hlo_wire}}")
    rows.append({{"dispatch": "ragged-2lvl", "wire_dtype": wire or "f32",
                  "us": float(np.median(ts) * 1e6),
                  "wire_bytes": meas, "hlo_fwd_bytes": hlo_wire,
                  "wire_bytes_intra": float(m.obs.wire_bytes_intra),
                  "wire_bytes_inter": float(m.obs.wire_bytes_inter),
                  "dropped": float(m.obs.dropped),
                  "imbalance": float(m.obs.imbalance)}})

for d in ("capacity", "ragged", "ragged-2lvl"):
    f32 = next(r for r in rows if r["dispatch"] == d
               and r["wire_dtype"] == "f32")
    b16 = next(r for r in rows if r["dispatch"] == d
               and r["wire_dtype"] == "bf16")
    ratio = b16["wire_bytes"] / f32["wire_bytes"]
    assert 0.4 <= ratio <= 0.6, f"{{d}}: bf16 wire ratio {{ratio}}"
print("RESULTJSON " + json.dumps(rows))
"""


def _materializes_mh(fn, *args, min_rows: int, hidden: int) -> bool:
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            s = getattr(v.aval, "shape", ())
            if len(s) == 2 and s[1] == hidden and s[0] >= min_rows:
                return True
    return False


def run(quick: bool = False) -> list[dict]:
    import dataclasses

    from repro.configs.base import MoEConfig
    from repro.core import fmoe

    t = T // 2 if quick else T
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(1), (t, DM))
    for dispatch in ("capacity", "ragged"):
        cfg = MoEConfig(num_experts=E, top_k=K, d_expert_hidden=DH,
                        dispatch=dispatch)
        params = fmoe.fmoe_init(jax.random.PRNGKey(0), DM, cfg)
        for impl in ("pallas", "fused"):
            def loss(p, x, impl=impl, cfg=cfg):
                y, _ = fmoe.fmoe_apply(p, x, cfg, impl=impl)
                return (y ** 2).mean()

            step = jax.jit(jax.value_and_grad(loss))
            res = timeit(step, params, x)
            mh = _materializes_mh(jax.value_and_grad(loss), params, x,
                                  min_rows=t * K, hidden=DH)
            row = {"impl": impl, "dispatch": dispatch, "us": res["us"],
                   "std_us": res["std_us"], "materializes_mh": mh,
                   "tokens": t, "backend": jax.default_backend()}
            rows.append(row)
            emit(f"fig10_{dispatch}_{impl}", row["us"],
                 f"fwd+bwd materializes_MH={mh}")
            assert (impl == "fused") == (not mh), (
                "fused step must not materialize (M, H); two-pass must")
    rows += _run_dist(quick)
    return rows


def _run_dist(quick: bool) -> list[dict]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    t = T // 2 if quick else T
    script = _DIST_SCRIPT.format(w=W, e=E, t=t, dm=DM, dh=DH, k=K)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    rows = json.loads(out.stdout.strip().split("RESULTJSON ")[1].splitlines()[0])
    for r in rows:
        r.update(impl="einsum", distributed=True, ranks=W,
                 backend=jax.default_backend())
        split = ("" if "wire_bytes_inter" not in r else
                 f" intra={r['wire_bytes_intra']:.0f}"
                 f" inter={r['wire_bytes_inter']:.0f}")
        emit(f"fig10_dist_{r['dispatch']}_{r['wire_dtype']}", r["us"],
             f"wire_bytes={r['wire_bytes']:.0f} "
             f"hlo_fwd_bytes={r['hlo_fwd_bytes']:.0f} "
             f"imbalance={r['imbalance']:.2f}" + split)
    return rows
