"""Benchmark utilities: paper-style timing (warm-up + 16 reps, §5.1)."""
from __future__ import annotations

import os
import time

import jax
import numpy as np


def smoke_mode() -> bool:
    """CI smoke runs (benchmarks/run.py --smoke) only care that every
    registered fig script still executes end to end — timings are noise on
    shared runners, so reps collapse to the minimum."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def timeit(fn, *args, reps: int = 16, warmup: int = 3) -> dict:
    """Median wall time per call in microseconds (paper runs 16 reps)."""
    if smoke_mode():
        reps, warmup = 1, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    return {"us": float(np.median(ts) * 1e6), "std_us": float(ts.std() * 1e6)}


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
