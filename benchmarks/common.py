"""Benchmark utilities: paper-style timing (warm-up + 16 reps, §5.1),
plus the shared metrics sink every fig script's rows land in
(repro.obs.sink — benchmarks/run.py points it at <out>/metrics.jsonl)."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

_RESULTS_DIR: str | None = None
_SINK = None


def set_results_dir(path: str | None) -> None:
    """Route :func:`record` / :func:`emit` telemetry to
    ``<path>/metrics.jsonl`` (None closes the sink)."""
    global _RESULTS_DIR, _SINK
    if _SINK is not None:
        _SINK.close()
        _SINK = None
    _RESULTS_DIR = path


def _sink():
    global _SINK
    if _SINK is None and _RESULTS_DIR is not None:
        from repro.obs import JsonlSink
        os.makedirs(_RESULTS_DIR, exist_ok=True)
        _SINK = JsonlSink(os.path.join(_RESULTS_DIR, "metrics.jsonl"),
                          append=True)
    return _SINK


def record(rec: dict) -> None:
    """Emit one telemetry record to the shared benchmark sink (no-op until
    :func:`set_results_dir` has pointed it somewhere)."""
    s = _sink()
    if s is not None:
        s.emit(rec)


def smoke_mode() -> bool:
    """CI smoke runs (benchmarks/run.py --smoke) only care that every
    registered fig script still executes end to end — timings are noise on
    shared runners, so reps collapse to the minimum."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def timeit(fn, *args, reps: int = 16, warmup: int = 3) -> dict:
    """Median wall time per call in microseconds (paper runs 16 reps)."""
    if smoke_mode():
        reps, warmup = 1, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    return {"us": float(np.median(ts) * 1e6), "std_us": float(ts.std() * 1e6)}


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
    record({"kind": "bench", "name": name, "us": us, "derived": derived})
