"""Paper Fig 3: GeMM throughput vs batch size.

Validates the premise of the reordered computation (§4): matmul throughput
only approaches peak when the per-expert batch is large — the motivation for
batching all of an expert's tokens into one GeMM.  CPU-scaled dims (the
paper used d_m=1024, d_h=4096 on V100); the qualitative claim is the
monotone throughput growth with batch size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

D_M, D_H = 512, 2048
BATCHES = [1, 4, 16, 64, 256, 1024]


def run(quick: bool = False) -> list[dict]:
    w = jax.random.normal(jax.random.PRNGKey(0), (D_M, D_H), jnp.float32)
    f = jax.jit(lambda x, w: x @ w)
    rows = []
    batches = BATCHES[:4] if quick else BATCHES
    for nb in batches:
        x = jax.random.normal(jax.random.PRNGKey(1), (nb, D_M), jnp.float32)
        t = timeit(f, x, w)
        gflops = 2 * nb * D_M * D_H / (t["us"] * 1e-6) / 1e9
        emit(f"fig3_gemm_b{nb}", t["us"], f"{gflops:.1f}GFLOP/s")
        rows.append({"batch": nb, "us": t["us"], "gflops": gflops,
                     "backend": jax.default_backend()})
    # the paper's point: large-batch GeMM must beat tiny-batch throughput
    assert rows[-1]["gflops"] > 3 * rows[0]["gflops"], rows
    return rows
