"""Guard against silent tier-1 rot (ISSUE 4 satellite, extended in ISSUE 5).

``scripts/ci.sh`` runs ``pytest -m tier1``, which silently shrinks to
nothing if a module listed in ``tests/conftest.py TIER1_MODULES`` is
renamed, deleted, or stops collecting (an import error inside a test file
only *deselects* it from a marker run).  This script fails fast when

* a listed module has no ``tests/<module>.py`` file, or
* a listed module collects zero tests, or
* a listed module would *silently skip every test* — e.g. all of its tests
  are hypothesis property tests and the matrix job's env lacks
  ``hypothesis``, so the shim (tests/_hypothesis_compat.py) decorated each
  one with an unconditional skip.  Such a module is green in CI while
  verifying nothing.

Usage: ``python scripts/check_tier1.py`` from the repo root (ci.sh does).
"""
from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TESTS = os.path.join(ROOT, "tests")


def tier1_modules() -> set[str]:
    sys.path.insert(0, TESTS)
    try:
        import conftest
        return set(conftest.TIER1_MODULES)
    finally:
        sys.path.pop(0)


class _Scan:
    """Collection-time census: tests per module, and which of them already
    carry an unconditional ``skip`` marker (the hypothesis-shim pattern)."""

    def __init__(self, modules: set[str]):
        self.counts = {m: 0 for m in modules}
        self.skipped = {m: 0 for m in modules}

    def pytest_collection_modifyitems(self, config, items):
        for item in items:
            mod = os.path.basename(str(item.fspath)).removesuffix(".py")
            if mod not in self.counts:
                continue
            self.counts[mod] += 1
            if any(mark.name == "skip" for mark in item.own_markers):
                self.skipped[mod] += 1


def main() -> int:
    modules = tier1_modules()
    missing = sorted(m for m in modules
                     if not os.path.exists(os.path.join(TESTS, f"{m}.py")))
    if missing:
        print(f"tier-1 modules without a test file: {missing}")
        return 1
    sys.path.insert(0, os.path.join(ROOT, "src"))
    os.chdir(ROOT)
    import pytest
    scan = _Scan(modules)
    code = pytest.main(
        ["--collect-only", "-q", "-p", "no:cacheprovider", "-m", "tier1"]
        + [os.path.join("tests", f"{m}.py") for m in sorted(modules)],
        plugins=[scan])
    empty = sorted(m for m, c in scan.counts.items() if c == 0)
    all_skip = sorted(m for m, c in scan.counts.items()
                      if c and scan.skipped[m] == c)
    if code not in (0, 5) or empty or all_skip:
        if empty:
            print(f"tier-1 modules collecting zero tests: {empty} "
                  f"(pytest exit {code})")
        if all_skip:
            print(f"tier-1 modules where EVERY test is marked skip "
                  f"(silently green, verifying nothing): {all_skip}")
        if code not in (0, 5):
            print(f"pytest collection failed (exit {code})")
        return 1
    total = sum(scan.counts.values())
    skipped = sum(scan.skipped.values())
    print(f"tier-1 ok: {len(modules)} modules, {total} tests collected"
          + (f" ({skipped} pre-marked skip)" if skipped else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
