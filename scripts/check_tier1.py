"""Guard against silent tier-1 rot (ISSUE 4 satellite).

``scripts/ci.sh`` runs ``pytest -m tier1``, which silently shrinks to
nothing if a module listed in ``tests/conftest.py TIER1_MODULES`` is
renamed, deleted, or stops collecting (an import error inside a test file
only *deselects* it from a marker run).  This script fails fast when

* a listed module has no ``tests/<module>.py`` file, or
* a listed module collects zero tests.

Usage: ``python scripts/check_tier1.py`` from the repo root (ci.sh does).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TESTS = os.path.join(ROOT, "tests")


def tier1_modules() -> set[str]:
    sys.path.insert(0, TESTS)
    try:
        import conftest
        return set(conftest.TIER1_MODULES)
    finally:
        sys.path.pop(0)


def main() -> int:
    modules = tier1_modules()
    missing = sorted(m for m in modules
                     if not os.path.exists(os.path.join(TESTS, f"{m}.py")))
    if missing:
        print(f"tier-1 modules without a test file: {missing}")
        return 1
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q", "-m", "tier1"]
        + [os.path.join("tests", f"{m}.py") for m in sorted(modules)],
        capture_output=True, text=True, cwd=ROOT, env=env)
    counts = {m: 0 for m in modules}
    for line in out.stdout.splitlines():
        m = re.match(r"tests[/\\](\w+)\.py::", line)
        if m and m.group(1) in counts:
            counts[m.group(1)] += 1
    empty = sorted(m for m, c in counts.items() if c == 0)
    if out.returncode not in (0, 5) or empty:
        print(out.stdout[-2000:])
        print(out.stderr[-2000:])
        print(f"tier-1 modules collecting zero tests: {empty or 'n/a'} "
              f"(pytest exit {out.returncode})")
        return 1
    total = sum(counts.values())
    print(f"tier-1 ok: {len(modules)} modules, {total} tests collected")
    return 0


if __name__ == "__main__":
    sys.exit(main())
