#!/usr/bin/env sh
# Lightweight CI entry point.
#
#   ./scripts/ci.sh            tier-1 subset (tests/conftest.py TIER1_MODULES),
#                              after failing fast on tier-1 rot (a listed
#                              module missing or collecting zero tests)
#   ./scripts/ci.sh --dist     the multi-rank test subset (fake host devices
#                              are set up by the tests themselves): expert
#                              parallelism, per-layer placement + decode
#                              shadowing, pipelined exchange, the ragged
#                              (dropless) a2a flat AND two-level on the
#                              2-node x 4-inner fake mesh, the router-zoo
#                              sweep (every cfg.router vs its single-rank
#                              oracle, dense==dispatched expert-choice,
#                              shared-expert zero-wire, DeepSeek-V2
#                              train+decode), and the shadowed serve step
#                              (tests/dist_utils.py is the shared harness)
#   ./scripts/ci.sh --faults   the fault drills only: SIGKILL mid-save +
#                              --resume, injected-NaN skip/retry, resume
#                              equivalence, drop-spike fallback, replan
#                              rollback (tests/test_resilience.py end to end)
#   ./scripts/ci.sh --serve    the serving loop: continuous batching +
#                              paged KV cache tests (tier-1's
#                              test_scheduler.py, including the mid-stream
#                              replan differential on fake devices) and the
#                              fig11 serving benchmark in smoke mode (all
#                              three admission modes must run; continuous
#                              must beat static tokens/sec)
#
# Extra args pass through to pytest.  Full verify stays:
#   PYTHONPATH=src python -m pytest -x -q
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# (test_hlo_regression.py is tier-1, so the matrix job already covers its
# multi-device subprocess cases — listing it here too would run the suite's
# most expensive tests twice per PR)
if [ "$1" = "--dist" ]; then
    shift
    exec python -m pytest -q tests/test_distributed.py tests/test_pipeline.py \
        tests/test_placement_dist.py tests/test_ragged_a2a.py \
        tests/test_hier_a2a.py tests/test_router_zoo.py \
        tests/test_serve.py::test_serve_step_shadowed_decode_bit_exact "$@"
fi

if [ "$1" = "--faults" ]; then
    shift
    exec python -m pytest -q tests/test_resilience.py "$@"
fi

if [ "$1" = "--serve" ]; then
    shift
    python -m pytest -q tests/test_scheduler.py "$@"
    exec python -m benchmarks.run --smoke --only fig11
fi

python scripts/check_tier1.py
python -m pytest -q -m tier1 "$@"
