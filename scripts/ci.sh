#!/usr/bin/env sh
# Lightweight CI: the import-safe tier-1 test subset (see tests/conftest.py
# TIER1_MODULES).  Full verify: PYTHONPATH=src python -m pytest -x -q
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m tier1 "$@"
